"""Cluster tier: consistent-hash routing, replication and failover over
the tagged wire — N daemons behind one client.

The paper ships SQLcached on "several large web sites", which means
fleets of daemons; this module is the routing layer in front of them
(the follow-up papers' clustering step, see PAPERS.md). Nothing here
runs on a daemon: the cluster is a CLIENT-side construct over the plain
tagged protocol (core/protocol.py), so daemons stay single-node simple
and any daemon can join any cluster.

Placement
---------
A :class:`HashRing` (consistent hashing, virtual nodes, deterministic
md5 points — stable across processes and PYTHONHASHSEED) maps keys to
nodes. Two granularities:

- A table WITHOUT an INT ``PARTITION BY`` column lives whole on
  ``ring.lookup(table, r)`` — its *group* of r nodes (``REPLICAS r``
  from the CREATE; the daemon stores r, we enforce it).
- A table WITH an INT partition column is *spread*: its keyspace is cut
  into ``NSLOTS`` cluster slots by the same multiplicative hash the
  daemon shards with (``shards.shard_of_host`` — so the daemon-side
  ``ALTER TABLE .. RETAIN SLOTS .. OF NSLOTS`` handover primitive
  computes the exact same membership), and slot s lives on
  ``ring.lookup(f"{table}/{s}", r)``. Adding or removing a node remaps
  only ~1/N of the slots — that is the point of the ring.
  (TEXT partition columns spread by per-daemon interner ids, which no
  two daemons share — those tables fall back to whole-table placement.)

Routing
-------
The client parses each statement (core/sqlparse.py) and reuses the
single-node shard planner for pruning: an equality on the partition
column (``planner.plan_shards``) routes to ONE slot group; everything
else fans out. Fan-out row reads choose a *cover* — one live member per
slot, deduped by node — and the merge keeps only each node's assigned
slots (rows carry the partition column, so the slot of every row is
recomputable client-side); ORDER BY re-sorts and LIMIT re-applies after
the merge. Fan-out aggregates go to every live node: COUNT/SUM divide
by the replication factor (each row has r live copies when healthy),
AVG is rewritten into SUM+COUNT and re-divided, MIN/MAX are
replication-immune. CREATE/DROP go to every node (any node may inherit
any slot later), so topology changes never need schema shipping.

Replication, acks, failover
---------------------------
Writes are mirrored to every live member of the target group UNDER THE
SAME TAG, in one pipelined flush; the result reported is the first
group member's. **Acknowledged means: the response block for the
statement's tag has been read back from every member that is still
considered live.** On connection loss or statement timeout the failed
node is marked down and the survivor's response — same tag, already
executed — stands in; that is the idempotent replay that makes a
kill -9 mid-pipeline lose zero acknowledged writes. Reads round-robin
across live group members; a failed read is re-sent to a surviving
replica with capped exponential backoff + jitter
(``protocol.backoff_delays``), and the survivor is thereby promoted
(the first live member of a group is its primary — death just filters
the list). Ordering: one ClusterClient preserves statement order per
node connection, so replicas converge and read-your-writes holds per
client; cross-client writes race exactly like memcached.

Topology changes
----------------
``add_node`` / ``remove_node`` recompute groups and move only the
remapped slots. A fresh node bulk-bootstraps via the daemon's
CHECKPOINT/RESTORE (checkpoint/store.py snapshots; RESTORE re-splits
rows through the RESHARD machinery and re-interns TEXT), then trims to
its owned slots with RETAIN; residual slots (and gains by already-
populated nodes, where RESTORE would clobber) move by row replay —
SELECT * from a surviving donor, slot-filtered, re-INSERTed. Checkpoint
directories default to a local tempdir; point ``checkpoint_dir`` at
shared storage when daemons span machines. ``SHOW CLUSTER`` (handled
client-side) reports nodes, health, tables, and group membership.

Known limits (documented, not surprises): fan-out write counts are
``sum // r`` and exact only while every replica is up; row replay moves
at most ``MAX_SELECT`` rows per table and drops tensor payloads (they
never cross the socket); a crashed node that restarts must rejoin via
``remove_node`` + ``add_node`` — promotion never un-happens by itself.
"""
from __future__ import annotations

import bisect
import dataclasses
import hashlib
import itertools
import json
import tempfile
import time
from typing import Any, Sequence

from repro.core import planner as PL
from repro.core import predicate as P
from repro.core import sqlparse as S
from repro.core import telemetry as TEL
from repro.core.protocol import (SQLCachedClient, _encode_arg,
                                 backoff_delays)
from repro.core.schema import ExpiryPolicy, TableSchema, make_schema
from repro.core.shards import shard_of_host

# cluster keyspace granularity for spread tables: partition values hash
# into this many slots, each placed on the ring independently. 64 keeps
# moved-data fractions fine-grained for small fleets while RETAIN lists
# stay short. Changing it changes placement — a cluster constant.
NSLOTS = 64


class ClusterError(RuntimeError):
    """Cluster-level failure: no live replica, unacknowledged write,
    un-mergeable fan-out, unknown table."""


def _norm_node(node) -> str:
    """Canonical node name 'host:port' from a string or (host, port)."""
    if isinstance(node, str):
        host, _, port = node.rpartition(":")
        return f"{host}:{int(port)}"
    host, port = node
    return f"{host}:{int(port)}"


def _node_addr(name: str) -> tuple[str, int]:
    host, _, port = name.rpartition(":")
    return host, int(port)


def _hash_point(key: str) -> int:
    """Deterministic 64-bit ring coordinate (md5 — stable across
    processes, unlike hash())."""
    return int.from_bytes(hashlib.md5(key.encode()).digest()[:8], "big")


class HashRing:
    """Consistent-hash ring with virtual nodes.

    Each node contributes ``vnodes`` points; a key maps to the first
    point clockwise, and :meth:`lookup` walks on to collect r DISTINCT
    nodes — the key's replica group. Adding/removing one node moves only
    the keys whose successor changed: ~1/N of the keyspace."""

    def __init__(self, nodes: Sequence[str] = (), vnodes: int = 64):
        self.vnodes = vnodes
        self._points: list[tuple[int, str]] = []
        self.nodes: list[str] = []
        for n in nodes:
            self.add(n)

    def add(self, node: str) -> None:
        if node in self.nodes:
            raise ValueError(f"node {node!r} already on the ring")
        self.nodes.append(node)
        for i in range(self.vnodes):
            bisect.insort(self._points, (_hash_point(f"{node}#{i}"), node))

    def remove(self, node: str) -> None:
        self.nodes.remove(node)
        self._points = [p for p in self._points if p[1] != node]

    def lookup(self, key: str, r: int = 1) -> tuple[str, ...]:
        """The r distinct nodes owning ``key``, clockwise from its hash
        (all nodes when r >= N). Order matters: index 0 is the primary."""
        if not self._points:
            raise ClusterError("empty ring")
        out: list[str] = []
        i = bisect.bisect_right(self._points, (_hash_point(key), "￿"))
        for k in range(len(self._points)):
            node = self._points[(i + k) % len(self._points)][1]
            if node not in out:
                out.append(node)
                if len(out) >= r:
                    break
        return tuple(out)


def _schema_of(stmt: S.CreateTable) -> TableSchema:
    """The daemon's CREATE lowering, run client-side so routing sees the
    same schema (incl. the defaulted partition column) as every node."""
    from repro.core.sqlparse import _PAYLOAD_DTYPES

    return make_schema(
        stmt.table, list(stmt.columns),
        [(n, s, _PAYLOAD_DTYPES[d]) for (n, s, d) in stmt.payloads],
        capacity=stmt.capacity, max_select=stmt.max_select,
        expiry=ExpiryPolicy(stmt.ttl, stmt.max_rows, stmt.ops_interval),
        indexes=stmt.indexes, shards=stmt.shards,
        partition_by=stmt.partition_by, replicas=stmt.replicas)


@dataclasses.dataclass
class _TableMeta:
    create_sql: str
    schema: TableSchema
    replicas: int
    spread: bool                 # slot-routed (INT partition column)
    pcol: str | None             # partition column (spread tables)
    # slot -> replica group (member order = promotion order); whole-table
    # tables keep one group under key None. Membership is STATIC between
    # topology calls — health only filters it, so promotion is simply
    # "first member not marked down".
    groups: dict[Any, tuple[str, ...]] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass
class _Pending:
    """One submitted cluster statement: its routing decision at submit
    time plus the per-member responses as they arrive."""

    sql: str
    params: tuple
    mode: str                    # local|create|drop|group_write|group_read
    #                              |fanall_write|agg_read|rows_fanout
    #                              |stats|any_read
    sqls: tuple[str, ...] = ()   # wire statements (AVG rewrites to 2)
    meta: _TableMeta | None = None
    groups: list = dataclasses.field(default_factory=list)
    slots: list = dataclasses.field(default_factory=list)
    node_slots: dict = dataclasses.field(default_factory=dict)
    div: int = 1                 # fan-all count deflation (replicas)
    agg: tuple | None = None
    order_by: str | None = None
    descending: bool = False
    limit: int | None = None
    local: dict | None = None
    resp: dict = dataclasses.field(default_factory=dict)
    #                              (gi, node, sub_i) -> dict | Exception


_AVG_RE = None  # built lazily (re import kept out of the hot path)


def _avg_rewrite(sql: str) -> tuple[str, str]:
    """AVG(col) fan-outs merge as sum(SUM)/sum(COUNT): rewrite the one
    statement into its SUM and COUNT(*) twins (same WHERE, same params)."""
    global _AVG_RE
    if _AVG_RE is None:
        import re
        _AVG_RE = re.compile(r"AVG\s*\(\s*(\w+)\s*\)", re.IGNORECASE)
    m = _AVG_RE.search(sql)
    if m is None:  # pragma: no cover — guarded by the caller
        raise ClusterError(f"cannot rewrite AVG statement: {sql!r}")
    return (sql[:m.start()] + f"SUM({m.group(1)})" + sql[m.end():],
            sql[:m.start()] + "COUNT(*)" + sql[m.end():])


class _ClusterBase:
    """Routing + merging shared by the sync and async clients (network
    I/O lives in the subclasses)."""

    def __init__(self, nodes, *, replica_default: int = 1,
                 statement_retries: int = 4, retry_base: float = 0.05,
                 retry_cap: float = 2.0):
        names = [_norm_node(n) for n in nodes]
        if len(set(names)) != len(names):
            raise ValueError("duplicate nodes")
        self._ring = HashRing(names)
        self._down: set[str] = set()
        self._tables: dict[str, _TableMeta] = {}
        self._parse_cache: dict[str, S.Statement] = {}
        self._tagno = 0
        self._rr = 0
        self.replica_default = replica_default
        self.statement_retries = statement_retries
        self.retry_base, self.retry_cap = retry_base, retry_cap

    # ----------------------------------------------------------- utilities
    def _next_tag(self) -> str:
        # one monotonic counter for the whole cluster: a mirrored write
        # carries the SAME tag on every member connection (idempotent
        # replay), and no connection ever sees a tag twice
        self._tagno += 1
        return f"c{self._tagno}"

    def _rr_next(self) -> int:
        self._rr += 1
        return self._rr

    def _live_nodes(self) -> list[str]:
        return [n for n in self._ring.nodes if n not in self._down]

    def _live(self, members) -> list[str]:
        return [m for m in members if m not in self._down]

    def _parse(self, sql: str) -> S.Statement:
        stmt = self._parse_cache.get(sql)
        if stmt is None:
            stmt = S.parse(sql)
            if len(self._parse_cache) < 4096:
                self._parse_cache[sql] = stmt
        return stmt

    def _meta(self, table: str) -> _TableMeta:
        m = self._tables.get(table)
        if m is None:
            raise ClusterError(
                f"unknown table {table!r}: CREATE it through this "
                f"ClusterClient so routing metadata exists")
        return m

    def _compute_groups(self, name: str, spread: bool,
                        replicas: int) -> dict:
        if spread:
            return {s: self._ring.lookup(f"{name}/{s}", replicas)
                    for s in range(NSLOTS)}
        return {None: self._ring.lookup(name, replicas)}

    def _register(self, sql: str, stmt: S.CreateTable) -> _TableMeta:
        schema = _schema_of(stmt)
        pby = schema.partition_by
        spread = (pby is not None
                  and not schema.column(pby).is_text)
        replicas = max(stmt.replicas, self.replica_default)
        meta = _TableMeta(sql, schema, replicas, spread,
                          pby if spread else None)
        meta.groups = self._compute_groups(stmt.table, spread, replicas)
        self._tables[stmt.table] = meta
        return meta

    # ------------------------------------------------------------- routing
    def _route(self, sql: str, params: Sequence[Any]) -> _Pending:
        params = tuple(params)
        if sql.strip().rstrip(";").upper() == "SHOW CLUSTER":
            return _Pending(sql, params, "local", local=self.show_cluster())
        stmt = self._parse(sql)
        p = _Pending(sql, params, "", sqls=(sql,))
        if isinstance(stmt, S.CreateTable):
            p.mode = "create"
            p.meta = self._register(sql, stmt)
            return p
        if isinstance(stmt, S.DropTable):
            p.mode = "drop"
            self._tables.pop(stmt.table, None)
            return p
        if isinstance(stmt, (S.AlterRetain, S.Checkpoint, S.Restore)):
            raise ClusterError(
                f"{type(stmt).__name__} is node-local admin — issue it on "
                f"a direct SQLCachedClient (the cluster uses it "
                f"internally during topology changes)")
        if isinstance(stmt, S.Explain):
            p.mode = "any_read"  # plans are identical on every node
            return p
        if isinstance(stmt, S.ShowStats):
            p.mode = "stats"
            p.meta = self._meta(stmt.table)
            return p
        meta = self._meta(stmt.table)
        p.meta = meta
        if isinstance(stmt, S.Insert):
            p.mode = "group_write"
            p.groups = [meta.groups[self._insert_slot(meta, stmt, params)]]
            return p
        if isinstance(stmt, S.Select):
            slot = self._where_slot(meta, stmt.where, params)
            p.agg = stmt.agg
            p.order_by, p.descending = stmt.order_by, stmt.descending
            p.limit = stmt.limit
            if slot is not _FANOUT:
                p.mode = "group_read"
                p.groups = [meta.groups[slot]]
                return p
            if stmt.agg is not None:
                p.mode = "agg_read"
                p.div = meta.replicas
                if stmt.agg[0].upper() == "AVG":
                    p.sqls = _avg_rewrite(sql)
                return p
            if not meta.spread:
                p.mode = "group_read"
                p.groups = [meta.groups[None]]
                return p
            # spread fan-out row read: merge must recompute each row's
            # slot and (for ORDER BY) re-sort — both need the columns
            cols = stmt.columns
            if cols and meta.pcol not in cols:
                raise ClusterError(
                    f"fan-out SELECT on spread table {stmt.table!r} must "
                    f"project the partition column {meta.pcol!r} (or *) "
                    f"so the merge can de-duplicate replicas")
            if stmt.order_by and cols and stmt.order_by not in cols:
                raise ClusterError(
                    f"fan-out ORDER BY {stmt.order_by!r} must be in the "
                    f"projection so the merge can re-sort")
            p.mode = "rows_fanout"
            return p
        if isinstance(stmt, (S.Update, S.Delete)):
            slot = self._where_slot(meta, stmt.where, params)
            if slot is not _FANOUT:
                p.mode = "group_write"
                p.groups = [meta.groups[slot]]
            elif not meta.spread:
                p.mode = "group_write"
                p.groups = [meta.groups[None]]
            else:
                p.mode = "fanall_write"
                p.div = meta.replicas
            return p
        if isinstance(stmt, (S.Expire, S.Flush, S.Reindex, S.AlterReshard)):
            if meta.spread:
                p.mode = "fanall_write"
                p.div = meta.replicas
            else:
                p.mode = "group_write"
                p.groups = [meta.groups[None]]
            return p
        raise ClusterError(f"unroutable statement: {sql!r}")

    def _insert_slot(self, meta: _TableMeta, stmt: S.Insert, params):
        if not meta.spread:
            return None
        try:
            idx = stmt.columns.index(meta.pcol)
        except ValueError:
            return self._slot_of(0)  # defaulted partition value
        node = stmt.values[idx]
        if isinstance(node, P.Const):
            v = node.value
        elif isinstance(node, P.Param):
            v = params[node.index]
        else:
            raise ClusterError(
                f"cluster INSERT needs a literal or ? for partition "
                f"column {meta.pcol!r} (a row lives on exactly one group)")
        return self._slot_of(v)

    @staticmethod
    def _slot_of(v) -> int:
        return shard_of_host(int(v), NSLOTS)

    def _where_slot(self, meta: _TableMeta, where, params):
        """The single cluster slot a WHERE prunes to, or _FANOUT. Reuses
        the single-node shard planner: same eq-on-partition-column rule,
        same hash."""
        if not meta.spread or where is None:
            return None if not meta.spread else _FANOUT
        route = PL.plan_shards(meta.schema, where)
        if route.key is None:
            return _FANOUT
        return self._slot_of(route.key.resolve(params))

    # ----------------------------------------------------------- assembling
    def _plan_sends(self, p: _Pending):
        """Expand one pending statement into (node, tag, sql, key) sends.
        Called at collect/dispatch time so it sees current health."""
        sends: list[tuple[str, str, str, tuple]] = []
        if p.mode == "local":
            return sends
        if p.mode in ("create", "drop", "fanall_write"):
            live = self._live_nodes()
            if not live:
                raise ClusterError("no live nodes")
            p.groups = [tuple(live)]
            tag = self._next_tag()
            for n in live:
                sends.append((n, tag, p.sqls[0], (0, n, 0)))
        elif p.mode == "group_write":
            for gi, members in enumerate(p.groups):
                live = self._live(members)
                if not live:
                    raise ClusterError(
                        f"no live replica for {p.sql!r} "
                        f"(group {tuple(members)})")
                tag = self._next_tag()  # SAME tag on every mirror
                for m in live:
                    sends.append((m, tag, p.sqls[0], (gi, m, 0)))
        elif p.mode in ("group_read", "any_read"):
            groups = p.groups or [tuple(self._live_nodes())]
            p.groups = groups
            for gi, members in enumerate(groups):
                live = self._live(members)
                if not live:
                    raise ClusterError(
                        f"no live replica for {p.sql!r} "
                        f"(group {tuple(members)})")
                reader = live[self._rr_next() % len(live)]
                sends.append((reader, self._next_tag(), p.sqls[0],
                              (gi, reader, 0)))
        elif p.mode in ("agg_read", "stats"):
            live = self._live_nodes()
            if not live:
                raise ClusterError("no live nodes")
            p.groups = [tuple(live)]
            for n in live:
                for si, q in enumerate(p.sqls):
                    sends.append((n, self._next_tag(), q, (0, n, si)))
        elif p.mode == "rows_fanout":
            # cover assignment: every slot read exactly once, deduped by
            # node — the merge keeps only each node's assigned slots
            meta = p.meta
            assign: dict[str, set[int]] = {}
            for slot, members in meta.groups.items():
                live = self._live(members)
                if not live:
                    raise ClusterError(
                        f"no live replica for slot {slot} of "
                        f"{meta.schema.name!r}")
                assign.setdefault(
                    live[self._rr_next() % len(live)], set()).add(slot)
            p.node_slots = assign
            for n in assign:
                sends.append((n, self._next_tag(), p.sqls[0], (0, n, 0)))
        else:  # pragma: no cover
            raise ClusterError(f"bad mode {p.mode!r}")
        return sends

    # -------------------------------------------------------------- merging
    def _merge(self, p: _Pending) -> dict:
        """Fold per-member responses into ONE result dict. Assumes the
        transport layer already ran fallbacks; raises ClusterError when a
        required response is missing and RuntimeError (verbatim) when the
        authoritative member reported a statement error."""
        if p.mode == "local":
            return p.local
        if p.mode in ("create", "drop"):
            return self._first_of_group(p, 0)
        if p.mode == "group_write":
            res = None
            for gi in range(len(p.groups)):
                res = self._first_of_group(p, gi)
            return res
        if p.mode in ("group_read", "any_read"):
            return self._first_of_group(p, 0)
        if p.mode == "fanall_write":
            counts, value = [], None
            for (gi, n, si), r in sorted(p.resp.items()):
                r = self._raise_err(r)
                counts.append(r["count"])
                if value is None:
                    value = r["value"]
            if not counts:
                raise ClusterError(f"write unacknowledged: {p.sql!r}")
            return {"count": sum(counts) // max(1, p.div),
                    "value": value, "rows": []}
        if p.mode == "stats":
            per = {n: self._raise_err(r)["value"]
                   for (gi, n, si), r in sorted(p.resp.items())}
            return {"count": len(per), "value": {"cluster_stats": per},
                    "rows": []}
        if p.mode == "agg_read":
            return self._merge_agg(p)
        if p.mode == "rows_fanout":
            return self._merge_rows(p)
        raise ClusterError(f"bad mode {p.mode!r}")  # pragma: no cover

    @staticmethod
    def _raise_err(r):
        if isinstance(r, Exception):
            raise r
        return r

    def _first_of_group(self, p: _Pending, gi: int) -> dict:
        """The group's authoritative response: first member IN GROUP
        ORDER that answered — i.e. the (possibly just-promoted) primary."""
        members = p.groups[gi]
        for m in members:
            r = p.resp.get((gi, m, 0))
            if r is not None:
                return self._raise_err(r)
        raise ClusterError(
            f"no replica of group {tuple(members)} answered: {p.sql!r}")

    def _merge_agg(self, p: _Pending) -> dict:
        fn = p.agg[0].upper()
        vals: list[list[Any]] = [[] for _ in p.sqls]
        for (gi, n, si), r in sorted(p.resp.items()):
            r = self._raise_err(r)
            vals[si].append(r["value"])
        if not vals[0]:
            raise ClusterError(f"no node answered: {p.sql!r}")
        nums = [v for v in vals[0] if v is not None]
        if fn == "AVG":
            total = sum(v for v in vals[0] if v is not None)
            cnt = sum(v for v in vals[1] if v is not None)
            value = (total / cnt) if cnt else 0.0
        elif fn in ("COUNT", "SUM"):
            value = sum(nums)
            if p.div > 1:
                # every row has `replicas` live copies when healthy
                value = (value // p.div if isinstance(value, int)
                         else value / p.div)
        elif fn == "MIN":
            value = min(nums) if nums else None
        elif fn == "MAX":
            value = max(nums) if nums else None
        else:
            raise ClusterError(f"unmergeable aggregate {fn!r}")
        return {"count": 0, "value": value, "rows": []}

    def _merge_rows(self, p: _Pending) -> dict:
        pcol = p.meta.pcol
        rows: list[dict] = []
        for (gi, n, si), r in sorted(p.resp.items()):
            r = self._raise_err(r)
            owned = p.node_slots.get(n, set())
            for row in r["rows"]:
                if self._slot_of(row[pcol]) in owned:
                    rows.append(row)
        if p.order_by:
            rows.sort(key=lambda row: row[p.order_by],
                      reverse=p.descending)
        if p.limit is not None:
            rows = rows[: p.limit]
        return {"count": len(rows), "value": None, "rows": rows}

    # --------------------------------------------------------------- health
    def mark_down(self, node: str) -> None:
        self._down.add(_norm_node(node))

    def mark_up(self, node: str) -> None:
        self._down.discard(_norm_node(node))

    def show_cluster(self) -> dict:
        """The SHOW CLUSTER report (client-side — this layer owns the
        topology). ``value`` mirrors what a VALUE row would carry."""
        nodes = [{"node": n,
                  "status": "down" if n in self._down else "up"}
                 for n in self._ring.nodes]
        tables = {}
        for t, m in self._tables.items():
            primaries: dict[str, int] = {}
            for members in m.groups.values():
                live = self._live(members)
                if live:
                    primaries[live[0]] = primaries.get(live[0], 0) + 1
            tables[t] = {"replicas": m.replicas, "spread": m.spread,
                         "slots": NSLOTS if m.spread else 1,
                         "partition_by": m.pcol,
                         "primary_of": primaries}
        return {"count": len(nodes), "rows": [],
                "value": {"nodes": nodes, "nslots": NSLOTS,
                          "tables": tables}}


_FANOUT = object()  # sentinel: statement visits every slot


class ClusterClient(_ClusterBase):
    """Synchronous cluster client: one :class:`SQLCachedClient` per
    daemon, consistent-hash routing, write mirroring, read failover and
    live topology changes. See the module docstring for semantics.

    ``execute`` is a one-statement pipeline; :meth:`pipeline` batches —
    statements fan out per node in one flush each and responses merge in
    submission order."""

    def __init__(self, nodes, *, timeout: float = 30.0,
                 connect_retries: int = 5, retry_base: float = 0.05,
                 retry_cap: float = 2.0, statement_retries: int = 4,
                 replica_default: int = 1,
                 checkpoint_dir: str | None = None):
        super().__init__(nodes, replica_default=replica_default,
                         statement_retries=statement_retries,
                         retry_base=retry_base, retry_cap=retry_cap)
        self.timeout = timeout
        self.connect_retries = connect_retries
        self._conns: dict[str, SQLCachedClient] = {}
        self._ckdir = checkpoint_dir
        self._ckno = 0

    # ------------------------------------------------------------ transport
    def _conn(self, node: str) -> SQLCachedClient:
        c = self._conns.get(node)
        if c is None:
            host, port = _node_addr(node)
            try:
                c = SQLCachedClient(
                    host, port, timeout=self.timeout,
                    connect_retries=self.connect_retries,
                    retry_base=self.retry_base, retry_cap=self.retry_cap)
            except OSError:
                self.mark_down(node)
                raise
            self._conns[node] = c
        return c

    def _drop_conn(self, node: str) -> None:
        c = self._conns.pop(node, None)
        if c is not None:
            try:
                c._sock.close()
            except OSError:
                pass

    def _fail_node(self, node: str) -> None:
        self.mark_down(node)
        self._drop_conn(node)

    def _exec_on(self, node: str, sql: str,
                 params: Sequence[Any] = ()) -> dict:
        """One tagged statement on one node (reconnect-once). Used by
        fallback reads and topology plumbing; raises ConnectionError
        (caller decides about marking down) or RuntimeError (server ERR)."""
        for attempt in (0, 1):
            conn = self._conn(node)
            tag = self._next_tag()
            frame = [f"EXEC#{tag} {sql}"]
            frame += [_encode_arg(v) for v in params]
            frame.append(f"GO#{tag}")
            try:
                conn._sock.sendall(("\r\n".join(frame) + "\r\n").encode())
                return conn._read_result(tag)
            except OSError as e:
                self._drop_conn(node)
                if attempt:
                    raise ConnectionError(f"{node}: {e}") from e
        raise AssertionError  # pragma: no cover

    # ------------------------------------------------------------ execution
    def execute(self, sql: str, params: Sequence[Any] = ()) -> dict:
        pl = self.pipeline()
        pl.execute(sql, params)
        res = pl.collect(return_exceptions=True)[0]
        if isinstance(res, Exception):
            raise res
        return res

    def pipeline(self) -> "ClusterPipeline":
        return ClusterPipeline(self)

    def warmup(self, table: str, like: str | None = None) -> int:
        """Pre-plan ``table``'s executors on EVERY live node serving it
        (``WARMUP t [LIKE ...]`` fan-out — reads load-balance across
        replicas, so a single-node WARMUP would leave the others cold).
        Returns the total number of newly compiled executables."""
        sql = f"WARMUP {table}"
        if like is not None:
            sql += " LIKE '" + like.replace("'", "''") + "'"
        members: set[str] = set()
        meta = self._tables.get(table)
        if meta is not None:
            for mem in meta.groups.values():
                members.update(mem)
        else:
            members.update(self._ring.nodes)
        new = 0
        for node in sorted(members):
            if node in self._down:
                continue
            try:
                new += int(self._exec_on(node, sql)["count"])
            except (ConnectionError, OSError):
                self._fail_node(node)
        return new

    def metrics(self, table: str | None = None) -> dict:
        """Fan ``SHOW METRICS [t]`` out to every live node and merge the
        telemetry reports into one pane of glass. Raw histogram buckets
        SUM across nodes (exact) and percentiles are recomputed from the
        merged buckets — never percentile-of-percentile
        (``telemetry.merge_reports``). With a table, only the nodes of
        its replica groups are asked (like :meth:`warmup`); nodes that
        answer ERR (e.g. a table they don't serve) are skipped."""
        sql = "SHOW METRICS" + (f" {table}" if table is not None else "")
        members: set[str] = set()
        meta = self._tables.get(table) if table is not None else None
        if meta is not None:
            for mem in meta.groups.values():
                members.update(mem)
        else:
            members.update(self._ring.nodes)
        reports = []
        for node in sorted(members):
            if node in self._down:
                continue
            try:
                rep = self._exec_on(node, sql)["value"]
            except (ConnectionError, OSError):
                self._fail_node(node)
                continue
            except RuntimeError:
                continue  # node ERR'd (no such table there) — skip it
            if isinstance(rep, dict):
                reports.append(rep)
        return TEL.merge_reports(reports)

    def ping_all(self, deadline: float | None = None) -> dict[str, bool]:
        """Probe every ring node; marks failures down (and successful
        probes up). The sync health check behind SHOW CLUSTER."""
        out = {}
        for n in list(self._ring.nodes):
            try:
                c = self._conn(n)
                if deadline is not None:
                    c._sock.settimeout(deadline)
                try:
                    ok = c.ping()
                finally:
                    if deadline is not None:
                        c._sock.settimeout(self.timeout)
            except OSError:
                ok = False
            out[n] = ok
            if ok:
                self.mark_up(n)
            else:
                self._fail_node(n)
        return out

    def close(self) -> None:
        for n in list(self._conns):
            c = self._conns.pop(n)
            try:
                c.close()
            except OSError:
                pass

    # ------------------------------------------------------------ fallbacks
    def _finish(self, p: _Pending) -> dict:
        """Run read fallbacks for missing responses, then merge."""
        if p.mode in ("group_read", "any_read"):
            for gi, members in enumerate(p.groups):
                got = any((gi, m, 0) in p.resp for m in members)
                if not got:
                    node, res = self._read_retry(members, p.sqls[0],
                                                 p.params, p.sql)
                    p.resp[(gi, node, 0)] = res
        elif p.mode == "rows_fanout":
            missing = [slot for slot, members in p.meta.groups.items()
                       if not any(self._slot_answered(p, slot, n)
                                  for n in p.node_slots)]
            if missing:
                self._rows_fallback(p, missing)
        elif p.mode == "agg_read":
            # a dead node's shard of the data survives on its replicas —
            # which DID answer; fan-all agg just folds what it got (the
            # /replicas deflation is documented healthy-cluster-exact)
            pass
        return self._merge(p)

    def _slot_answered(self, p: _Pending, slot: int, node: str) -> bool:
        return (slot in p.node_slots.get(node, ())
                and isinstance(p.resp.get((0, node, 0)), dict))

    def _rows_fallback(self, p: _Pending, slots: list) -> None:
        """Re-cover slots whose reader died: reassign each to a surviving
        member and re-execute (with backoff) once per new node."""
        assign: dict[str, set] = {}
        for slot in slots:
            live = self._live(p.meta.groups[slot])
            if not live:
                raise ClusterError(
                    f"no live replica for slot {slot} of "
                    f"{p.meta.schema.name!r}")
            assign.setdefault(
                live[self._rr_next() % len(live)], set()).add(slot)
        for node, owned in assign.items():
            _, res = self._read_retry(
                [node] + [m for s in owned
                          for m in p.meta.groups[s] if m != node],
                p.sqls[0], p.params, p.sql)
            p.node_slots[node] = p.node_slots.get(node, set()) | owned
            p.resp[(0, node, 0)] = res

    def _read_retry(self, members, sql, params,
                    orig: str) -> tuple[str, dict]:
        """Failover read: try live members round-robin with capped
        exponential backoff + jitter; server ERRs surface verbatim (a
        statement error is not a node failure)."""
        last: Exception | None = None
        for delay in itertools.chain(
                [0.0], backoff_delays(self.statement_retries,
                                      self.retry_base, self.retry_cap)):
            if delay:
                time.sleep(delay)
            live = self._live(members)
            if not live:
                break
            node = live[self._rr_next() % len(live)]
            try:
                return node, self._exec_on(node, sql, params)
            except (ConnectionError, OSError) as e:
                self._fail_node(node)
                last = e
        raise ClusterError(
            f"no live replica answered {orig!r} "
            f"(group {tuple(members)}): {last}")

    # ------------------------------------------------------------- topology
    def _ck_path(self, table: str) -> str:
        if self._ckdir is None:
            self._ckdir = tempfile.mkdtemp(prefix="sqlcached-cluster-ck-")
        self._ckno += 1
        return f"{self._ckdir}/{table}-{self._ckno}"

    def add_node(self, node) -> dict:
        """Join a FRESH daemon: replay every CREATE on it, remap the
        ring (~1/N of slots move), bulk-bootstrap via CHECKPOINT/RESTORE
        from the donor covering the most gained slots, RETAIN down to the
        owned set, row-replay the remainder, then trim the shrunk old
        holders. Returns a per-table movement report."""
        name = _norm_node(node)
        old = {t: dict(m.groups) for t, m in self._tables.items()}
        self._ring.add(name)
        self.mark_up(name)
        report: dict[str, dict] = {}
        for t, meta in self._tables.items():
            self._exec_on(name, meta.create_sql)
            new_groups = self._compute_groups(t, meta.spread, meta.replicas)
            gained = [k for k, mem in new_groups.items()
                      if name in mem and name not in old[t].get(k, ())]
            moved = self._bootstrap(name, t, meta, gained, old[t],
                                    fresh=True,
                                    owned=[k for k, mem in new_groups.items()
                                           if name in mem])
            self._trim_losers(t, meta, old[t], new_groups, exclude=(name,))
            meta.groups = new_groups
            # pre-plan the joiner's executors before the ring routes
            # traffic at it — a fresh node must not pay first-hit
            # compiles inside the serving path (core/execache.py)
            self._exec_on(name, f"WARMUP {t}")
            report[t] = {"gained": len(gained), "moved_rows": moved}
        return report

    def remove_node(self, node) -> dict:
        """Take a node out — decommission or post-crash cleanup (works
        whether or not the process still runs). Each group it served
        gains the next ring successor, bootstrapped from a surviving
        member (CHECKPOINT/RESTORE when the gainer holds nothing of the
        table, row replay otherwise). Returns a movement report."""
        name = _norm_node(node)
        self._ring.remove(name)
        self.mark_down(name)
        self._drop_conn(name)
        report: dict[str, dict] = {}
        for t, meta in self._tables.items():
            old_groups = dict(meta.groups)
            new_groups = self._compute_groups(t, meta.spread, meta.replicas)
            gains: dict[str, list] = {}
            for k, mem in new_groups.items():
                for m in mem:
                    if m not in old_groups.get(k, ()):
                        gains.setdefault(m, []).append(k)
            moved = 0
            for gainer, keys in gains.items():
                moved += self._bootstrap(gainer, t, meta, keys, old_groups,
                                         fresh=False,
                                         owned=[k for k, mem
                                                in new_groups.items()
                                                if gainer in mem])
            meta.groups = new_groups
            report[t] = {"gainers": len(gains), "moved_rows": moved}
        self.mark_down(name)  # stays down until re-added
        return report

    def _bootstrap(self, dest: str, table: str, meta: _TableMeta,
                   keys: list, old_groups: dict, *, fresh: bool,
                   owned: list) -> int:
        """Move the data for ``keys`` (slots, or [None] for whole-table)
        onto ``dest``. ``fresh`` means dest verifiably holds nothing of
        the table, enabling the bulk CHECKPOINT/RESTORE path."""
        if not keys:
            return 0
        donors: dict[Any, str] = {}
        for k in keys:
            d = next((m for m in old_groups.get(k, ())
                      if m not in self._down and m != dest), None)
            if d is not None:
                donors[k] = d
        if not donors:
            return 0  # nothing live to copy from (data only on dest)
        moved = 0
        replay_keys = dict(donors)
        if fresh:
            # bulk path: one donor's snapshot, restored through the
            # daemon's RESHARD re-split, then trimmed to the owned slots
            by_donor: dict[str, list] = {}
            for k, d in donors.items():
                by_donor.setdefault(d, []).append(k)
            best = max(by_donor, key=lambda d: len(by_donor[d]))
            ck = self._ck_path(table)
            r = self._exec_on(best, f"CHECKPOINT {table} TO '{ck}'")
            self._exec_on(dest, f"RESTORE {table} FROM '{ck}'")
            moved += r["count"]
            if meta.spread:
                slot_list = ",".join(str(s) for s in sorted(owned))
                self._exec_on(dest, f"ALTER TABLE {table} RETAIN SLOTS "
                                    f"{slot_list} OF {NSLOTS}")
            # the snapshot delivered EVERY owned slot best was a member
            # of — not just the slots donor-mapped to best; replaying
            # those too would duplicate rows on dest
            for k in list(replay_keys):
                if best in old_groups.get(k, ()):
                    replay_keys.pop(k)
            if not meta.spread:
                return moved
        # row replay for the rest (and for non-fresh gainers, where a
        # whole-table RESTORE would clobber the slots they already hold)
        by_donor = {}
        for k, d in replay_keys.items():
            by_donor.setdefault(d, []).append(k)
        for d, ks in by_donor.items():
            moved += self._replay_rows(table, meta, ks, d, dest)
        return moved

    def _replay_rows(self, table: str, meta: _TableMeta, keys: list,
                     donor: str, dest: str) -> int:
        """SELECT * on the donor, keep rows of the moving slots, INSERT
        them on dest (pipelined). Bounded by MAX_SELECT; payloads don't
        cross the wire — documented limits of the replay path."""
        res = self._exec_on(donor, f"SELECT * FROM {table}")
        rows = res["rows"]
        if meta.spread and keys != [None]:
            want = set(keys)
            rows = [r for r in rows
                    if self._slot_of(r[meta.pcol]) in want]
        if not rows:
            return 0
        cols = [c.name for c in meta.schema.columns]
        sql = (f"INSERT INTO {table} ({', '.join(cols)}) "
               f"VALUES ({', '.join('?' for _ in cols)})")
        frames: list[str] = []
        tags: list[str] = []
        for row in rows:
            tag = self._next_tag()
            frames.append(f"EXEC#{tag} {sql}")
            frames += [_encode_arg(row[c]) for c in cols]
            frames.append(f"GO#{tag}")
            tags.append(tag)
        conn = self._conn(dest)
        conn._sock.sendall(("\r\n".join(frames) + "\r\n").encode())
        for tag in tags:
            conn._read_result(tag)
        return len(rows)

    def _trim_losers(self, table: str, meta: _TableMeta, old: dict,
                     new: dict, exclude=()) -> None:
        losers: dict[str, list] = {}
        for k, mem in old.items():
            for m in mem:
                if m in exclude or m in self._down:
                    continue
                if m not in new.get(k, ()):
                    losers.setdefault(m, []).append(k)
        for m, lost in losers.items():
            if meta.spread:
                owned = sorted(k for k, mem in new.items() if m in mem)
                if owned:
                    slots = ",".join(str(s) for s in owned)
                    self._exec_on(m, f"ALTER TABLE {table} RETAIN SLOTS "
                                     f"{slots} OF {NSLOTS}")
                else:
                    self._exec_on(m, f"FLUSH {table}")
            else:
                self._exec_on(m, f"FLUSH {table}")


class ClusterPipeline:
    """Pipelined cluster statements: each ``execute`` routes immediately;
    ``collect`` fans the frames out per node (one flush per node), reads
    every node's responses in ITS submission order, runs failover for
    anything a dead node left unanswered, and merges per-statement
    results back into global submission order — exactly one entry per
    queued statement, always."""

    def __init__(self, cc: ClusterClient):
        self._cc = cc
        self._stmts: list[_Pending] = []
        self.results: list = []

    def __len__(self) -> int:
        return len(self._stmts)

    def execute(self, sql: str, params: Sequence[Any] = ()) -> int:
        self._stmts.append(self._cc._route(sql, params))
        return len(self._stmts) - 1

    def collect(self, return_exceptions: bool = False) -> list:
        cc = self._cc
        bufs: dict[str, list[str]] = {}
        expect: dict[str, list[tuple[str, _Pending, tuple]]] = {}
        route_errors: dict[int, Exception] = {}
        for i, p in enumerate(self._stmts):
            try:
                for node, tag, sql, key in cc._plan_sends(p):
                    frame = [f"EXEC#{tag} {sql}"]
                    frame += [_encode_arg(v) for v in p.params]
                    frame.append(f"GO#{tag}")
                    bufs.setdefault(node, []).extend(frame)
                    expect.setdefault(node, []).append((tag, p, key))
            except ClusterError as e:
                route_errors[i] = e
        # one flush per node; a dead socket fails the node, not the batch
        for node, lines in bufs.items():
            try:
                cc._conn(node)._sock.sendall(
                    ("\r\n".join(lines) + "\r\n").encode())
            except OSError:
                cc._fail_node(node)
        # drain each node in its own submission order
        for node, exps in expect.items():
            conn = cc._conns.get(node)
            if conn is None or node in cc._down:
                continue
            for tag, p, key in exps:
                try:
                    p.resp[key] = conn._read_result(tag)
                except RuntimeError as e:
                    p.resp[key] = e  # server ERR: an answer, not a death
                except OSError:
                    cc._fail_node(node)
                    break
        # failover + merge, in submission order
        out: list = []
        errs: list[Exception] = []
        for i, p in enumerate(self._stmts):
            if i in route_errors:
                out.append(route_errors[i])
                errs.append(route_errors[i])
                continue
            try:
                out.append(cc._finish(p))
            except Exception as e:  # noqa: BLE001 — per-stmt isolation
                out.append(e)
                errs.append(e)
        self._stmts.clear()
        self.results = out
        if errs and not return_exceptions:
            raise errs[0]
        return out

    def __enter__(self) -> "ClusterPipeline":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.collect(return_exceptions=True)


class AsyncClusterClient(_ClusterBase):
    """Asyncio cluster client: one :class:`AsyncSQLCachedClient` per
    node. ``execute`` coroutines may run concurrently (``gather``) —
    each fans out to its target nodes through the per-node multiplexing
    clients, so N in-flight statements still cost one round trip. Write
    mirroring, acks and read failover follow the sync client's
    semantics; topology changes (add/remove node) live on the sync
    client only."""

    def __init__(self, nodes, *, timeout: float = 30.0,
                 connect_retries: int = 5, retry_base: float = 0.05,
                 retry_cap: float = 2.0, statement_retries: int = 4,
                 replica_default: int = 1):
        super().__init__(nodes, replica_default=replica_default,
                         statement_retries=statement_retries,
                         retry_base=retry_base, retry_cap=retry_cap)
        self.timeout = timeout
        self.connect_retries = connect_retries
        self._conns: dict[str, Any] = {}

    async def _conn(self, node: str):
        from repro.core.protocol import AsyncSQLCachedClient

        c = self._conns.get(node)
        if c is None:
            host, port = _node_addr(node)
            try:
                c = await AsyncSQLCachedClient.connect(
                    host, port, connect_retries=self.connect_retries,
                    retry_base=self.retry_base, retry_cap=self.retry_cap)
            except OSError:
                self.mark_down(node)
                raise
            self._conns[node] = c
        return c

    async def _drop_conn(self, node: str) -> None:
        c = self._conns.pop(node, None)
        if c is not None:
            try:
                await c.close()
            except Exception:  # noqa: BLE001
                pass

    async def _fail_node(self, node: str) -> None:
        self.mark_down(node)
        await self._drop_conn(node)

    async def _exec_on(self, node: str, sql: str,
                       params: Sequence[Any] = ()) -> dict:
        import asyncio

        conn = await self._conn(node)
        try:
            return await asyncio.wait_for(conn.execute(sql, params),
                                          self.timeout)
        except asyncio.TimeoutError as e:
            raise ConnectionError(f"{node}: statement timeout") from e

    async def execute(self, sql: str, params: Sequence[Any] = ()) -> dict:
        import asyncio

        p = self._route(sql, params)
        if p.mode == "local":
            return p.local
        sends = self._plan_sends(p)

        async def one(node, tag, q, key):
            try:
                p.resp[key] = await self._exec_on(node, q, p.params)
            except RuntimeError as e:
                p.resp[key] = e
            except (ConnectionError, OSError):
                await self._fail_node(node)

        await asyncio.gather(*(one(*s) for s in sends))
        # read failover: re-send anything a dead node left unanswered
        if p.mode in ("group_read", "any_read"):
            for gi, members in enumerate(p.groups):
                if not any((gi, m, 0) in p.resp for m in members):
                    node, res = await self._read_retry(members, p.sqls[0],
                                                       p.params, sql)
                    p.resp[(gi, node, 0)] = res
        elif p.mode == "rows_fanout":
            missing = [s for s, members in p.meta.groups.items()
                       if not any(
                           s in p.node_slots.get(n, ())
                           and isinstance(p.resp.get((0, n, 0)), dict)
                           for n in p.node_slots)]
            for slot in missing:
                node, res = await self._read_retry(
                    p.meta.groups[slot], p.sqls[0], p.params, sql)
                p.node_slots[node] = (p.node_slots.get(node, set())
                                      | {slot})
                p.resp[(0, node, 0)] = res
        return self._merge(p)

    async def _read_retry(self, members, sql, params, orig):
        import asyncio

        last: Exception | None = None
        for delay in itertools.chain(
                [0.0], backoff_delays(self.statement_retries,
                                      self.retry_base, self.retry_cap)):
            if delay:
                await asyncio.sleep(delay)
            live = self._live(members)
            if not live:
                break
            node = live[self._rr_next() % len(live)]
            try:
                return node, await self._exec_on(node, sql, params)
            except (ConnectionError, OSError) as e:
                await self._fail_node(node)
                last = e
        raise ClusterError(
            f"no live replica answered {orig!r} "
            f"(group {tuple(members)}): {last}")

    async def ping_all(self, deadline: float = 2.0) -> dict[str, bool]:
        import asyncio

        out = {}
        for n in list(self._ring.nodes):
            try:
                c = await self._conn(n)
                out[n] = await c.ping(deadline=deadline)
            except (ConnectionError, OSError, asyncio.TimeoutError):
                out[n] = False
            if out[n]:
                self.mark_up(n)
            else:
                await self._fail_node(n)
        return out

    async def close(self) -> None:
        for n in list(self._conns):
            await self._drop_conn(n)
