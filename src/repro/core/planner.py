"""Query planner: lowers a parsed WHERE clause into an explicit plan IR.

This is the single routing point for the query engine (it absorbed the
``classify_fusable`` calls that used to be duplicated across
``table.select/update/delete``). A WHERE lowers to exactly one of:

``IndexProbe``   an equality term on a hash-indexed column anchors the
                 statement: probe ONE bucket of the device-resident index
                 (kernels/hashidx), verify the remaining conjuncts on the
                 <= bucket_cap candidates. O(1) in table capacity. Carries
                 a ``fallback`` scan plan — executors ``lax.cond`` onto it
                 when the index is stale (bucket overflow), so the choice
                 is revisited per dispatch WITHOUT a host sync.
``FusedScan``    a conjunction of <= 4 eq/range terms over int32 columns:
                 the grid-tiled Pallas relscan (one fused pass: predicate
                 x validity x count x compaction).
``GenericScan``  everything else: the jnp masked-scan over
                 ``predicate.eval_predicate`` (always correct, never
                 fast).

Plans are frozen dataclasses — hashable, so they ride inside executor
cache keys and jit static arguments; :func:`plan_where` is memoized per
(schema, where). The planner is *static* (host-side, pre-trace): runtime
concerns that can flip a plan (a float bound to an int column's ``?``)
stay in the executors, which demote to the fallback at trace time.

``columns_of`` reports an AST's column footprint; the daemon reuses it to
stamp read/write footprints onto ``StatementShape`` so the batch
scheduler can fence at column rather than table granularity.

Sharded tables (``schema.shards > 1``, core/shards.py) add one routing
layer ABOVE the plan: :func:`plan_shards` lowers the same WHERE into a
``ShardRoute`` — *pruned* when an equality conjunct anchors the
statement to the hash of the partition column (execute on exactly ONE
shard, so lookup latency is independent of the shard count), *fan-out*
otherwise (execute on every shard via ``vmap`` over the stacked shard
states and merge the partials). The within-shard plan is the ordinary
``plan_where`` result; EXPLAIN reports both layers.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import predicate as P
from repro.core.schema import RESERVED_COLUMNS, SQL_TYPES, TableSchema

MAX_RESIDUAL = 8  # index-probe verification budget (terms beyond the key)


@dataclasses.dataclass(frozen=True)
class GenericScan:
    """Evaluate the WHERE with the generic jnp masked scan."""

    reason: str = ""

    kind = "generic-scan"

    @property
    def columns(self) -> tuple[str, ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class FusedScan:
    """One fused relscan pass over the conjunction ``scan.terms``."""

    scan: P.FusedScan

    kind = "fused-scan"

    @property
    def columns(self) -> tuple[str, ...]:
        return self.scan.columns


@dataclasses.dataclass(frozen=True)
class IndexProbe:
    """Probe the hash index of ``column`` with the key term's value and
    verify ``residual`` on the candidates. ``fallback`` is the scan plan
    executors cond onto when the index is stale."""

    column: str
    key: P.FusedTerm                      # the anchoring `col == value`
    residual: tuple[P.FusedTerm, ...]     # remaining conjuncts
    fallback: "FusedScan | GenericScan"

    kind = "index-probe"

    @property
    def columns(self) -> tuple[str, ...]:
        return (self.column,) + tuple(t.col for t in self.residual)


Plan = IndexProbe | FusedScan | GenericScan


@dataclasses.dataclass(frozen=True)
class ShardRoute:
    """Shard routing for one WHERE against a sharded table (the layer
    ABOVE the Plan IR): ``key`` is the equality term on the partition
    column when the statement prunes to the single shard holding that
    key's hash (None = fan-out across all ``n_shards``). The within-shard
    execution still follows a :data:`Plan` (``plan_where``)."""

    column: str                    # the partition column
    key: P.FusedTerm | None        # eq term on it, None -> fan-out
    n_shards: int

    @property
    def pruned(self) -> bool:
        return self.key is not None

    @property
    def kind(self) -> str:
        return "pruned" if self.pruned else f"fan-out x {self.n_shards}"


def int_columns(schema: TableSchema) -> frozenset:
    """The relscan/hashidx-eligible column set: int32-typed user columns
    (INT and interned TEXT) plus the reserved clock columns."""
    return frozenset(
        c.name for c in schema.columns
        if np.dtype(SQL_TYPES[c.sql_type.upper()]) == np.int32
    ) | frozenset(RESERVED_COLUMNS)


@functools.lru_cache(maxsize=4096)
def plan_where(schema: TableSchema, where: P.Node | None,
               ranked: bool = False) -> Plan:
    """Lower ``where`` to a Plan for ``schema`` (memoized — this is the
    prepared-statement planner cache). ``ranked`` marks an ORDER BY
    statement: ranked reads need the full match mask for ``top_k``, so
    they always scan — the rule lives HERE so the executors, the batched
    routing and EXPLAIN can't drift apart."""
    if ranked:
        return GenericScan("ORDER BY requires the ranked scan")
    if where is None:
        # match-all: one jnp op, nothing to fuse or probe
        return GenericScan("no WHERE")
    ints = int_columns(schema)
    fused = P.classify_fusable(where, ints, max_terms=1 + MAX_RESIDUAL)
    if fused is None:
        return GenericScan("not a fusable conjunction")
    small = fused if len(fused.terms) <= 4 else None
    key = next((t for t in fused.terms
                if t.op == "==" and t.col in schema.indexes), None)
    if key is not None:
        residual = tuple(t for t in fused.terms if t is not key)
        fb = (FusedScan(small) if small is not None
              else GenericScan("conjunction exceeds the 4-term kernel"))
        return IndexProbe(key.col, key, residual, fb)
    if small is not None:
        return FusedScan(small)
    return GenericScan("conjunction exceeds the 4-term kernel")


def _coerce_int_literals(node: P.Node | None) -> P.Node | None:
    """Numeric-equal float literals coerced to int for ROUTING only: an
    int32 partition column compared against ``5.0`` matches exactly the
    rows an int ``5`` matches, so the route may hash the int — the
    within-shard predicate keeps the original (exact-compare) literal.
    Non-integral floats are left alone: they match nothing on an int
    column, and any route is correct for an empty result."""
    def coerce(v):
        if (isinstance(v, float) and v.is_integer()
                and abs(v) < 2 ** 31):
            return int(v)
        return v

    return P.map_consts(node, coerce)


@functools.lru_cache(maxsize=4096)
def plan_shards(schema: TableSchema, where: P.Node | None) -> ShardRoute:
    """Lower ``where`` to a ShardRoute for a sharded ``schema`` (memoized
    like :func:`plan_where`). A statement prunes iff a top-level equality
    conjunct anchors the partition column — exactly the rows that can
    match live in ``shard_of(key)``; everything else (ranges on the
    partition column, ORs, no WHERE) must visit every shard. Pruning is
    value-directed: the shard id itself is computed from the bound value
    at execution time (device-side, so batched statements route
    per-row). Float LITERALS that are numerically integral (``k = 5.0``)
    are coerced to the column dtype before classification, so they prune
    like ``k = 5`` instead of silently demoting to fan-out.

    Under mesh placement (PR 7) this route IS the device decision: a
    pruning route resolves to one lane and therefore to that lane's
    device (``shards.lane_devices`` — what EXPLAIN reports as
    ``device``), while a fan-out route becomes one all-device
    ``shard_map`` dispatch (EXPLAIN reports ``devices``)."""
    col = schema.partition_by
    n = schema.shards
    if where is None or col is None:
        return ShardRoute(col or "", None, n)
    ints = int_columns(schema)
    fused = P.classify_fusable(_coerce_int_literals(where), ints,
                               max_terms=1 + MAX_RESIDUAL)
    key = None
    if fused is not None:
        key = next((t for t in fused.terms if t.op == "==" and t.col == col),
                   None)
    return ShardRoute(col, key, n)


def as_fused(plan: Plan) -> P.FusedScan | None:
    """The P.FusedScan equivalent of ``plan`` when one exists (<= 4
    terms) — the shim behind ``table._fused_plan`` and the batched-DML
    eq-shape detection."""
    if isinstance(plan, FusedScan):
        return plan.scan
    if isinstance(plan, IndexProbe):
        terms = (plan.key,) + plan.residual
        if len(terms) <= 4:
            return P.FusedScan(terms)
    return None


def columns_of(node: P.Node | None) -> frozenset:
    """Every column name an expression/predicate AST touches."""
    out: set[str] = set()

    def walk(n):
        if n is None:
            return
        if isinstance(n, P.Col):
            out.add(n.name)
        elif isinstance(n, (P.BinOp, P.And, P.Or)):
            walk(n.left), walk(n.right)
        elif isinstance(n, P.Not):
            walk(n.child)
        elif isinstance(n, P.Between):
            walk(n.expr), walk(n.low), walk(n.high)
        elif isinstance(n, P.InList):
            walk(n.expr)
            for i in n.items:
                walk(i)
        elif isinstance(n, P.Func):
            for a in n.args:
                walk(a)

    walk(node)
    return frozenset(out)


def explain(schema: TableSchema, where: P.Node | None,
            ranked: bool = False) -> dict:
    """EXPLAIN payload for one WHERE clause against ``schema``: the chosen
    plan, the columns it reads, (for probes) the fallback, and (for
    sharded tables) the shard route — ``pruned -> shard k`` when the key
    is a constant, ``pruned`` when it binds a ``?``, ``fan-out x n``
    otherwise."""
    plan = plan_where(schema, where, ranked)
    out = {"plan": plan.kind, "table": schema.name,
           "columns": sorted(columns_of(where))}
    if isinstance(plan, IndexProbe):
        out["index"] = plan.column
        out["residual"] = sorted(t.col for t in plan.residual)
        out["fallback"] = plan.fallback.kind
    elif isinstance(plan, FusedScan):
        out["terms"] = [f"{t.col} {t.op}" for t in plan.scan.terms]
    elif plan.reason:
        out["reason"] = plan.reason
    if schema.shards > 1:
        from repro.core import shards as SH  # late: shards imports planner

        route = plan_shards(schema, where)
        out["shards"] = schema.shards
        out["partition_by"] = route.column
        if route.pruned:
            kind, v = route.key.value
            if kind == "const":
                sid = int(SH.shard_of_host(int(v), schema.shards))
                out["shard_route"] = f"pruned -> shard {sid}"
            else:
                out["shard_route"] = "pruned"
        else:
            out["shard_route"] = route.kind
    return out
