"""Cross-connection batch scheduler — the daemon's admission queue.

The paper's daemon multiplexes every web-app connection into a single
execution stream (§3). PR 1 made that stream cheap to batch
(``SQLCached.executemany`` dispatches W same-shape statements in ONE
jitted call); this module is the piece that *fills* those batches from
the network: an admission queue collects in-flight statements across ALL
connections, groups them by (table, statement shape) via the daemon's
:meth:`~repro.core.daemon.SQLCached.shape_key` hook, and dispatches each
group through ``executemany`` (``per_statement=True``, so every client
still gets its own COUNT/ROW/VALUE response). Singleton and unbatchable
groups fall back to plain ``execute``. Together with the protocol
layer's per-connection response flushing this replaces the old global
``_exec_lock``.

Ordering contract
-----------------
Admission order is preserved wherever it is observable:

* a READ joins its shape's open group iff no WRITE group on the same
  table opened after that group (reads commute with reads);
* a WRITE joins its shape's open group iff NO group at all on the same
  table opened after it (same-shape writes batch through ``executemany``,
  whose executors keep sequential semantics among themselves);
* admin statements (CREATE/DROP/EXPIRE/FLUSH) and unparseable SQL are
  barriers — they never merge and nothing reorders across them.

Groups dispatch strictly in open order, so per-connection and per-table
orderings both hold; cross-table reordering (which no client can observe
through the wire protocol) is allowed. Results are lazy, so a dispatch
returns as soon as the device work is enqueued — the response flushers
materialize rows off the event loop.
"""
from __future__ import annotations

import asyncio
from collections import deque
from typing import Any, Sequence

from repro.core.daemon import SQLCached, StatementShape


class _Item:
    __slots__ = ("sql", "params", "future", "shape")

    def __init__(self, sql: str, params: tuple, future: asyncio.Future,
                 shape: StatementShape | None):
        self.sql = sql
        self.params = params
        self.future = future
        self.shape = shape


class _Group:
    __slots__ = ("seq", "shape", "items")

    def __init__(self, seq: int, shape: StatementShape | None, items: list):
        self.seq = seq
        self.shape = shape
        self.items = items


class BatchScheduler:
    """Admission queue + shape-grouping dispatcher over one SQLCached.

    ``batching=False`` degrades to a per-statement serial executor (every
    statement its own group) — the wire protocol stays pipelined, but no
    cross-connection fusion happens; benchmarks use this to separate the
    two effects. ``max_batch`` bounds group size (and therefore the
    executor bucket sizes that get compiled)."""

    def __init__(self, db: SQLCached, *, batching: bool = True,
                 max_batch: int = 64, max_admit: int = 4096):
        self.db = db
        self.batching = batching
        self.max_batch = max_batch
        self.max_admit = max_admit
        self._q: deque[_Item] = deque()
        self._wake = asyncio.Event()
        self._task: asyncio.Task | None = None
        self._closed = False
        self.stats = {"admitted": 0, "batches": 0, "grouped_statements": 0,
                      "singles": 0, "max_group": 0}

    # ------------------------------------------------------------ lifecycle
    async def start(self) -> None:
        if self._task is None:
            self._closed = False
            self._task = asyncio.create_task(self._loop())

    async def stop(self) -> None:
        self._closed = True
        self._wake.set()
        if self._task is not None:
            try:
                await self._task
            finally:
                self._task = None
        while self._q:
            it = self._q.popleft()
            if not it.future.done():
                it.future.set_exception(
                    ConnectionError("scheduler stopped"))

    # ------------------------------------------------------------ admission
    def submit(self, sql: str, params: Sequence[Any] = ()) -> asyncio.Future:
        """Enqueue one statement; returns a future resolving to its lazy
        :class:`~repro.core.daemon.Result` (or raising the statement's
        error). Must be called from the scheduler's event loop."""
        fut = asyncio.get_running_loop().create_future()
        if self._closed:
            fut.set_exception(ConnectionError("scheduler stopped"))
            return fut
        try:
            shape = self.db.shape_key(sql)
        except Exception:
            shape = None  # unparseable: barrier; execute() re-raises for us
        self._q.append(_Item(sql, tuple(params), fut, shape))
        self.stats["admitted"] += 1
        self._wake.set()
        return fut

    # ------------------------------------------------------------- planning
    def _plan(self, items: list[_Item]) -> list[_Group]:
        groups: list[_Group] = []
        open_by_key: dict[tuple, _Group] = {}
        last_any: dict[str, int] = {}
        last_write: dict[str, int] = {}
        barrier = -1
        for it in items:
            sh = it.shape
            if sh is None or not sh.batchable or not self.batching:
                seq = len(groups)
                groups.append(_Group(seq, sh, [it]))
                if sh is None:
                    barrier = seq
                else:
                    last_any[sh.table] = seq
                    last_write[sh.table] = seq
                continue
            tbl = sh.table
            g = open_by_key.get(sh.key)
            fence = last_any.get(tbl, -1) if sh.is_write \
                else last_write.get(tbl, -1)
            if (g is not None and len(g.items) < self.max_batch
                    and g.seq >= barrier and g.seq >= fence):
                g.items.append(it)
            else:
                seq = len(groups)
                g = _Group(seq, sh, [it])
                groups.append(g)
                open_by_key[sh.key] = g
                last_any[tbl] = seq
                if sh.is_write:
                    last_write[tbl] = seq
        return groups

    # ------------------------------------------------------------- dispatch
    async def _run_single(self, it: _Item) -> None:
        try:
            res = await asyncio.to_thread(self.db.execute, it.sql, it.params)
        except Exception as e:  # noqa: BLE001 — statement error, not ours
            if not it.future.done():
                it.future.set_exception(e)
        else:
            if not it.future.done():
                it.future.set_result(res)

    async def _dispatch(self, g: _Group) -> None:
        items = g.items
        self.stats["batches"] += 1
        if len(items) > self.stats["max_group"]:
            self.stats["max_group"] = len(items)
        if len(items) == 1:
            self.stats["singles"] += 1
            await self._run_single(items[0])
            return
        self.stats["grouped_statements"] += len(items)
        try:
            params_list = [it.params for it in items]
            results = await asyncio.to_thread(
                self.db.executemany, items[0].sql, params_list,
                per_statement=True)
        except Exception:  # noqa: BLE001
            # one member's bad binding (wrong arity, bad type) must not
            # fail its groupmates: the batch raised before any state
            # mutation, so replay each statement alone — only the
            # offenders error (rare slow path)
            for it in items:
                await self._run_single(it)
            return
        for it, res in zip(items, results):
            if not it.future.done():
                it.future.set_result(res)

    async def _loop(self) -> None:
        while True:
            await self._wake.wait()
            self._wake.clear()
            if self._closed:
                return
            # one scheduling tick: let every runnable connection handler
            # drain its read buffer into the queue before cutting batches
            await asyncio.sleep(0)
            items: list[_Item] = []
            while self._q and len(items) < self.max_admit:
                items.append(self._q.popleft())
            if self._q:
                self._wake.set()  # leftovers past max_admit: next tick
            for g in self._plan(items):
                await self._dispatch(g)
