"""Cross-connection batch scheduler — the daemon's admission queue.

The paper's daemon multiplexes every web-app connection into a single
execution stream (§3). PR 1 made that stream cheap to batch
(``SQLCached.executemany`` dispatches W same-shape statements in ONE
jitted call); this module is the piece that *fills* those batches from
the network: an admission queue collects in-flight statements across ALL
connections, groups them by (table, statement shape) via the daemon's
:meth:`~repro.core.daemon.SQLCached.shape_key` hook, and dispatches each
group through ``executemany`` (``per_statement=True``, so every client
still gets its own COUNT/ROW/VALUE response). Singleton and unbatchable
groups fall back to plain ``execute``. Together with the protocol
layer's per-connection response flushing this replaces the old global
``_exec_lock``.

Ordering contract
-----------------
Admission order is preserved wherever it is observable. Fencing is at
COLUMN granularity, reusing the plan's table/column footprint that
``shape_key`` stamps on each statement (``reads``/``writes``; ``None`` =
whole table — INSERT/DELETE churn validity, admin is a hard barrier):

* a READ joins its shape's open group iff no group that WRITES a column
  it reads opened after that group (reads commute with reads, and with
  writes to columns they never look at);
* a WRITE joins its shape's open group iff no group that touches its
  write set — or writes its read set — opened after it (same-shape
  writes batch through ``executemany``, whose executors keep sequential
  semantics among themselves);
* admin statements (CREATE/DROP/EXPIRE/FLUSH) and unparseable SQL are
  barriers — they never merge and nothing reorders across them; EXPLAIN
  (no reads, no writes) merges with nothing but fences nothing.

Groups whose footprints conflict dispatch strictly in open order, so
per-connection orderings and every column-level data dependency hold;
reordering that no client can observe through the wire protocol
(cross-table, or across writes to disjoint columns) is allowed.
Auto-expiry cadence is per-statement (PR 2), so regrouping does not
change TTL semantics beyond the already documented batch-boundary
flexibility. Results are lazy, so a dispatch returns as soon as the
device work is enqueued — the response flushers materialize rows off the
event loop.

Concurrent waves
----------------
Groups are dispatched in *waves*: a wave is the longest prefix of
consecutive groups that pairwise COMMUTE — different tables, same table
with disjoint column footprints, or same (sharded) table with provably
disjoint shard-route sets (``SQLCached.group_shard_ids``: every
statement in each group prunes to a known shard set and the sets don't
intersect — independent-shard traffic from different connections
overlaps even when the column footprints collide). A wave's groups run
concurrently (``asyncio.gather`` over worker threads — jax device work
is enqueued asynchronously, so this overlaps the host-side dispatch
cost that dominates small statements); a conflicting group ends the
wave and waits. Admin statements and unparseable SQL stay hard
barriers: they are always a wave of one. Shard-pruned statements on one
table may observe a logical clock that differs by the wave's statement
count from strict admission order (clock ticks commute; same TTL
batch-boundary flexibility as above).

Execution lanes
---------------
Locking inside a wave is per SHARD, not per table (PR 5): a sharded
table's state lives in per-shard lane handles at the daemon
(``daemon._Table.lanes``), and a group whose shard route is provably
ONE shard (``SQLCached.group_shard_ids`` returns a singleton) acquires
only that lane's asyncio lock — so same-table groups on different
lanes hold disjoint locks and truly overlap, and the daemon executes
each against its own lane's buffers. A MULTI-shard group whose
statements each provably route to one lane splits into per-lane
sub-batches (``_split_group``, via ``SQLCached.item_lanes``) that
dispatch concurrently under their own lane locks — and since PR 7
places lanes on mesh devices, disjoint-lane overlap is real
multi-DEVICE overlap. Remaining fan-out / unknown-route groups take
the table's base lock plus every lane (whole-table exclusion),
unsharded tables keep their single lock, and acquisition follows one
global order (base, then lanes ascending) so concurrent groups cannot
deadlock. ``lane_locks=False`` restores the PR-4 single-lock regime
(the lane-bench baseline).

Admission window
----------------
``max_wait_us > 0`` holds the batch cut open while the OLDEST admitted
statement is younger than the window, letting groupmates arrive from
other connections; the deadline is per-statement, so a lone statement is
never held past ``max_wait_us`` and the default (0) dispatches every
tick exactly as before. The clock (``_now``) and the wait primitive
(``_wait_for_arrivals``) are injectable for deterministic tests.
"""
from __future__ import annotations

import asyncio
import os
import time
from collections import deque
from typing import Any, Sequence

from repro.core import telemetry as TEL
from repro.core.daemon import SQLCached, StatementShape
from repro.lint import lockorder as LK


class _Item:
    __slots__ = ("sql", "params", "future", "shape", "admitted_at", "trace")

    def __init__(self, sql: str, params: tuple, future: asyncio.Future,
                 shape: StatementShape | None, admitted_at: float = 0.0,
                 trace: "TEL.Trace | None" = None):
        self.sql = sql
        self.params = params
        self.future = future
        self.shape = shape
        self.admitted_at = admitted_at
        self.trace = trace


class _Group:
    __slots__ = ("seq", "shape", "items", "_shard_ids", "_lane")

    _UNSET = object()

    def __init__(self, seq: int, shape: StatementShape | None, items: list):
        self.seq = seq
        self.shape = shape
        self.items = items
        self._shard_ids = _Group._UNSET  # lazily computed, then cached
        self._lane = _Group._UNSET

    def shard_ids(self, db: SQLCached) -> frozenset | None:
        """The provable shard-id set of this group's statements (None =
        unknown / fan-out / unsharded table). Computed lazily at
        wave-build time — i.e. after every preceding wave (including
        CREATE/DROP barriers) has executed — and cached."""
        if self._shard_ids is _Group._UNSET:
            try:
                self._shard_ids = db.group_shard_ids(
                    self.shape, [it.params for it in self.items])
            except Exception:  # noqa: BLE001 — routing is best effort
                self._shard_ids = None
        return self._shard_ids

    def lane(self, db: SQLCached) -> int | None:
        """The execution lane the DAEMON will run this group on (None =
        the dispatch takes the whole table). This is ``db.group_lane``
        — the exact predicate ``_exec_mode`` uses — so the lock set
        below always covers what the dispatch actually touches (a
        single-shard group can still need a whole-table dispatch, e.g.
        an INSERT batch wider than one shard)."""
        if self._lane is _Group._UNSET:
            try:
                self._lane = db.group_lane(
                    self.shape, [it.params for it in self.items])
            except Exception:  # noqa: BLE001 — routing is best effort
                self._lane = None
        return self._lane


class _TableFences:
    """Per-table column-granular fence bookkeeping for one planning pass.

    Tracks, per column, the latest group that WROTE it and the latest
    group that TOUCHED it (read or wrote); ``*_all`` carry the groups
    whose footprint was unknown (None = whole table)."""

    __slots__ = ("write_col", "touch_col", "write_all", "touch_all",
                 "write_any")

    def __init__(self):
        self.write_col: dict[str, int] = {}
        self.touch_col: dict[str, int] = {}
        self.write_all = -1   # latest whole-table write
        self.touch_all = -1   # latest whole-table read-or-write
        self.write_any = -1   # latest write of ANY column

    def read_fence(self, reads) -> int:
        """Latest group a read with footprint ``reads`` must not precede."""
        if reads is None:
            return max(self.write_all, self.write_any)
        f = self.write_all
        for c in reads:
            f = max(f, self.write_col.get(c, -1))
        return f

    def write_fence(self, reads, writes) -> int:
        """Latest group a write (reads/writes footprints) must not
        precede: anything touching its write set, any write to its read
        set, and every whole-table group."""
        if reads is None or writes is None:
            f = self.touch_all
            for c in self.touch_col:
                f = max(f, self.touch_col[c])
            return max(f, self.write_any)
        f = max(self.write_all, self.touch_all)
        for c in writes:
            f = max(f, self.touch_col.get(c, -1))
        for c in reads:
            f = max(f, self.write_col.get(c, -1))
        return f

    def record(self, seq: int, reads, writes, is_write: bool) -> None:
        for fp, isw in ((reads, False), (writes, True)):
            if fp is None:
                self.touch_all = max(self.touch_all, seq)
                if isw or is_write:
                    self.write_all = max(self.write_all, seq)
                    self.write_any = max(self.write_any, seq)
                continue
            for c in fp:
                self.touch_col[c] = max(self.touch_col.get(c, -1), seq)
                if isw:
                    self.write_col[c] = max(self.write_col.get(c, -1), seq)
                    self.write_any = max(self.write_any, seq)


class BatchScheduler:
    """Admission queue + shape-grouping dispatcher over one SQLCached.

    ``batching=False`` degrades to a per-statement serial executor (every
    statement its own group) — the wire protocol stays pipelined, but no
    cross-connection fusion happens; benchmarks use this to separate the
    two effects. ``max_batch`` bounds group size (and therefore the
    executor bucket sizes that get compiled). ``max_wait_us`` bounds how
    long an admitted statement may wait for groupmates (0 = never)."""

    def __init__(self, db: SQLCached, *, batching: bool = True,
                 max_batch: int = 64, max_admit: int = 4096,
                 max_wait_us: int = 0, concurrency: bool | None = None,
                 lane_locks: bool = True):
        self.db = db
        self.batching = batching
        self.max_batch = max_batch
        self.max_admit = max_admit
        self.max_wait_us = max_wait_us
        if concurrency is None:  # env override so CI can run both regimes
            concurrency = os.environ.get(
                "REPRO_SCHED_CONCURRENCY", "1") != "0"
        self.concurrency = concurrency  # overlap commuting groups (waves)
        # lane_locks=False restores the PR-4 regime: one lock per table,
        # so same-table groups serialize even inside a wave (the
        # lane-bench baseline)
        self.lane_locks = lane_locks
        self._now = time.monotonic  # injectable (fake clocks in tests)
        self._q: deque[_Item] = deque()
        self._wake = asyncio.Event()
        self._task: asyncio.Task | None = None
        self._closed = False
        # per table: {"base": Lock, "lanes": {shard_id: Lock}} — see
        # _locks_for
        self._table_locks: dict[str, dict] = {}
        # Atomic counters (telemetry.Counters): waves dispatch groups
        # concurrently and render threads read these live, so plain
        # ``+=`` read-modify-writes would lose increments.
        self.stats = TEL.Counters(
            {"admitted": 0, "batches": 0, "grouped_statements": 0,
             "singles": 0, "max_group": 0, "window_waits": 0,
             "waves": 0, "overlapped_groups": 0, "max_wave": 0,
             "lane_dispatches": 0, "lane_splits": 0,
             "cold_solo": 0, "errors": 0})

    # ------------------------------------------------------------ lifecycle
    async def start(self) -> None:
        if self._task is None:
            self._closed = False
            self._task = asyncio.create_task(self._loop())

    async def stop(self) -> None:
        self._closed = True
        self._wake.set()
        if self._task is not None:
            try:
                await self._task
            finally:
                self._task = None
        while self._q:
            it = self._q.popleft()
            if not it.future.done():
                it.future.set_exception(
                    ConnectionError("scheduler stopped"))

    # ------------------------------------------------------------ admission
    def submit(self, sql: str, params: Sequence[Any] = (),
               trace: "TEL.Trace | None" = None) -> asyncio.Future:
        """Enqueue one statement; returns a future resolving to its lazy
        :class:`~repro.core.daemon.Result` (or raising the statement's
        error). Must be called from the scheduler's event loop."""
        fut = asyncio.get_running_loop().create_future()
        if self._closed:
            fut.set_exception(ConnectionError("scheduler stopped"))
            return fut
        if trace is not None:
            trace.mark("wire")   # EXEC receipt -> admission
            trace.sql = sql
        try:
            shape = self.db.shape_key(sql)
        except Exception:
            shape = None  # unparseable: barrier; execute() re-raises for us
        if trace is not None:
            trace.mark("parse")
            if shape is not None:
                trace.table, trace.kind = shape.table, shape.kind
        self._q.append(_Item(sql, tuple(params), fut, shape, self._now(),
                             trace))
        self.stats.add("admitted")
        self._wake.set()
        return fut

    # ------------------------------------------------------------- planning
    def _plan(self, items: list[_Item]) -> list[_Group]:
        groups: list[_Group] = []
        open_by_key: dict[tuple, _Group] = {}
        fences: dict[str, _TableFences] = {}
        barrier = -1
        for it in items:
            sh = it.shape
            if sh is None or not sh.batchable or not self.batching:
                seq = len(groups)
                groups.append(_Group(seq, sh, [it]))
                if sh is None:
                    barrier = seq
                elif sh.is_write or sh.reads is None or sh.reads:
                    # a statement with nothing to read or write (EXPLAIN)
                    # fences nothing; everything else unbatchable is a
                    # whole-table barrier
                    fences.setdefault(sh.table, _TableFences()).record(
                        seq, None, None, True)
                continue
            tf = fences.setdefault(sh.table, _TableFences())
            g = open_by_key.get(sh.key)
            fence = (tf.write_fence(sh.reads, sh.writes) if sh.is_write
                     else tf.read_fence(sh.reads))
            if (g is not None and len(g.items) < self.max_batch
                    and g.seq >= barrier and g.seq >= fence):
                g.items.append(it)
                tf.record(g.seq, sh.reads, sh.writes, sh.is_write)
            else:
                seq = len(groups)
                g = _Group(seq, sh, [it])
                groups.append(g)
                open_by_key[sh.key] = g
                tf.record(seq, sh.reads, sh.writes, sh.is_write)
        return groups

    # ------------------------------------------------------------- dispatch
    @staticmethod
    def _call_traced(fn, traces, *args, **kwargs):
        """Run ``fn`` in the worker thread with ``traces`` installed as
        the ambient dispatch context (so daemon/execache attribute
        exec_mode and cache events into them) and stamp the "execute"
        span on each trace when it returns."""
        if not traces:
            return fn(*args, **kwargs)
        with TEL.dispatch_span(traces):
            try:
                return fn(*args, **kwargs)
            finally:
                for tr in traces:
                    tr.mark("execute")

    async def _run_single(self, it: _Item) -> None:
        traces = [it.trace] if it.trace is not None else ()
        try:
            res = await asyncio.to_thread(
                self._call_traced, self.db.execute, traces, it.sql, it.params)
        except Exception as e:  # noqa: BLE001 — statement error, not ours
            self.stats.add("errors")
            if not it.future.done():
                it.future.set_exception(e)
        else:
            if not it.future.done():
                it.future.set_result(res)

    def _locks_for(self, g: _Group) -> list:
        """The ordered lock set one group must hold (per-shard execution
        lanes): a group that provably routes to ONE shard takes only that
        lane's lock — so same-table groups on different lanes run truly
        concurrently inside a wave; everything else on a sharded table
        takes the base lock plus every lane (whole-table exclusion); an
        unsharded table keeps its single base lock. Acquisition order is
        global (base, then lanes ascending), so concurrent groups can
        never deadlock."""
        table = g.shape.table if g.shape is not None else None
        if table is None:
            return []
        ent = self._table_locks.setdefault(
            table, {"base": LK.make_async_lock(f"sched:{table}:base"),
                    "lanes": {}})
        t = self.db.tables.get(table)
        n = t.schema.shards if t is not None else 1
        if n <= 1 or not self.lane_locks:
            return [ent["base"]]
        lanes = ent["lanes"]
        lane = g.lane(self.db)
        if lane is not None:
            # single-lane group: the daemon will execute it on exactly
            # this lane's state handle (db.group_lane IS the dispatch
            # decision _exec_mode reads, so lock and dispatch agree)
            self.stats.add("lane_dispatches")
            return [lanes.setdefault(
                lane, LK.make_async_lock(f"sched:{table}:lane{lane}"))]
        return [ent["base"]] + [
            lanes.setdefault(i, LK.make_async_lock(f"sched:{table}:lane{i}"))
            for i in range(n)]

    def _split_group(self, g: _Group) -> "list[_Group] | None":
        """Split a multi-shard group whose statements EACH provably
        route to one lane into per-lane sub-batches (None = the group
        stays whole). The sub-batches hold disjoint lane locks and
        dispatch concurrently — multi-shard traffic on one shape
        overlaps like singleton lane groups instead of serializing
        under base + every lane (on a mesh-placed table that means the
        sub-batches run on different DEVICES at once). Statements on
        different lanes touch disjoint shards, so the split preserves
        per-statement semantics; within a lane, admission order holds.
        Every sub-batch is re-verified through the daemon's own route
        predicate (``_Group.lane`` = ``db.group_lane``): a sub-batch
        the daemon would still dispatch whole-table (e.g. a padded
        INSERT wider than one shard) vetoes the split, so the lock set
        always covers the dispatch."""
        if (not self.concurrency or not self.lane_locks
                or g.shape is None or not g.shape.batchable
                or len(g.items) < 2 or g.lane(self.db) is not None):
            return None
        try:
            lanes = self.db.item_lanes(
                g.shape, [it.params for it in g.items])
        except Exception:  # noqa: BLE001 — routing is best effort
            return None
        if (lanes is None or any(ln is None for ln in lanes)
                or len(set(lanes)) < 2):
            return None
        by_lane: dict[int, list] = {}
        for it, ln in zip(g.items, lanes):
            by_lane.setdefault(ln, []).append(it)
        subs = []
        for ln, items in by_lane.items():
            sub = _Group(g.seq, g.shape, items)
            if sub.lane(self.db) != ln:
                return None
            subs.append(sub)
        return subs

    async def _dispatch(self, g: _Group) -> None:
        """Run one group — split into per-lane sub-batches when its
        statements provably land on disjoint lanes, whole otherwise."""
        subs = self._split_group(g)
        if subs is None:
            await self._dispatch_one(g)
            return
        self.stats.add("lane_splits")
        await asyncio.gather(*(self._dispatch_one(s) for s in subs))

    async def _dispatch_one(self, g: _Group) -> None:
        """Run one (sub-)group under its lane/table locks. Commuting
        makes the order inside a wave free; the locks keep each state
        handle's read-modify-write atomic — and disjoint-lane groups
        hold disjoint locks, so they truly overlap."""
        locks = self._locks_for(g)
        for it in g.items:
            if it.trace is not None:
                it.trace.mark("queue")   # admission -> lock acquisition
        for lk in locks:
            await lk.acquire()
        for it in g.items:
            if it.trace is not None:
                it.trace.mark("lock")    # lane/table lock wait
        try:
            await self._dispatch_inner(g)
        finally:
            for lk in reversed(locks):
                lk.release()

    async def _dispatch_inner(self, g: _Group) -> None:
        items = g.items
        self.stats.add("batches")
        self.stats.max("max_group", len(items))
        for it in items:
            if it.trace is not None:
                it.trace.group = len(items)
        if len(items) == 1:
            self.stats.add("singles")
            await self._run_single(items[0])
            return
        self.stats.add("grouped_statements", len(items))
        traces = [it.trace for it in items if it.trace is not None]
        try:
            params_list = [it.params for it in items]
            results = await asyncio.to_thread(
                self._call_traced, self.db.executemany, traces,
                items[0].sql, params_list, per_statement=True)
        except Exception:  # noqa: BLE001
            # one member's bad binding (wrong arity, bad type) must not
            # fail its groupmates: the batch raised before any state
            # mutation, so replay each statement alone — only the
            # offenders error (rare slow path)
            for it in items:
                await self._run_single(it)
            return
        for it, res in zip(items, results):
            if not it.future.done():
                it.future.set_result(res)

    # ------------------------------------------------------------- waves
    @staticmethod
    def _footprints_disjoint(a: StatementShape, b: StatementShape) -> bool:
        """Column-level commutation on one table: neither side's writes
        may touch what the other reads or writes (None = whole table)."""

        def touch(s):  # columns a shape touches at all; None = whole table
            if s.reads is None or s.writes is None:
                return None
            return s.reads | s.writes

        def conflicts(w, t):  # one side's writes vs the other's touches
            if w is not None and not w:
                return False   # writes nothing (reads commute with reads)
            if t is not None and not t:
                return False   # other side touches nothing (EXPLAIN)
            if w is None or t is None:
                return True    # whole-table on either side
            return bool(w & t)

        return not (conflicts(a.writes, touch(b))
                    or conflicts(b.writes, touch(a)))

    def _compatible(self, g: _Group, h: _Group) -> bool:
        """May ``g`` run concurrently with ``h``? Barriers never overlap;
        different tables always do; same-table groups need disjoint
        column footprints or provably disjoint shard routes."""
        for x in (g, h):
            if x.shape is None or x.shape.kind == "admin":
                return False
        if g.shape.table != h.shape.table:
            return True
        if self._footprints_disjoint(g.shape, h.shape):
            return True
        gs, hs = g.shard_ids(self.db), h.shard_ids(self.db)
        return gs is not None and hs is not None and not (gs & hs)

    def _is_cold(self, g) -> bool:
        """True when dispatching ``g`` would compile a new executor
        (its shape x placement is not pre-planned — execache.sigs). Cold
        groups dispatch in cold-only waves: a compile takes orders of
        magnitude longer than a replay, and under lane locks it would
        stall every warm groupmate sharing its wave. Best effort — stub
        dbs without ``group_warm`` and routing errors count as warm
        (old behavior)."""
        gw = getattr(self.db, "group_warm", None)
        if gw is None or g.shape is None or g.shape.kind == "admin":
            return False
        try:
            cold = not gw(g.shape, [it.params for it in g.items])
        except Exception:  # noqa: BLE001 — admission hints are best effort
            return False
        if cold:
            self.stats.add("cold_solo")
        return cold

    async def _dispatch_wave(self, wave: list) -> None:
        self.stats.add("waves")
        self.stats.max("max_wave", len(wave))
        if len(wave) > 1:
            for g in wave:
                for it in g.items:
                    if it.trace is not None:
                        it.trace.wave = len(wave)
        if len(wave) == 1:
            await self._dispatch(wave[0])
            return
        self.stats.add("overlapped_groups", len(wave))
        await asyncio.gather(*(self._dispatch(g) for g in wave))

    # ------------------------------------------------------------- windowing
    async def _wait_for_arrivals(self, timeout: float) -> None:
        """Park until new admissions or the window deadline (injectable —
        the fake-clock tests replace this and ``_now``)."""
        try:
            await asyncio.wait_for(self._wake.wait(), timeout)
        except asyncio.TimeoutError:
            pass

    async def _hold_window(self) -> None:
        """Latency-bounded admission: keep the cut open while the OLDEST
        waiter is younger than ``max_wait_us`` and the queue is not full.
        The deadline belongs to the oldest statement, so nobody — least
        of all a lone statement — waits past the window."""
        while (self._q and not self._closed
               and len(self._q) < self.max_admit):
            deadline = self._q[0].admitted_at + self.max_wait_us / 1e6
            remain = deadline - self._now()
            if remain <= 0:
                break
            self.stats.add("window_waits")
            self._wake.clear()
            await self._wait_for_arrivals(remain)
            # let every runnable connection handler drain its read buffer
            await asyncio.sleep(0)

    async def _loop(self) -> None:
        while True:
            await self._wake.wait()
            self._wake.clear()
            if self._closed:
                return
            # one scheduling tick: let every runnable connection handler
            # drain its read buffer into the queue before cutting batches
            await asyncio.sleep(0)
            if self.max_wait_us > 0:
                await self._hold_window()
                if self._closed:
                    return
            items: list[_Item] = []
            while self._q and len(items) < self.max_admit:
                items.append(self._q.popleft())
            if self._q:
                self._wake.set()  # leftovers past max_admit: next tick
            groups = self._plan(items)
            if not self.concurrency:
                for g in groups:
                    await self._dispatch(g)
                continue
            # wave dispatch: run the longest prefix of pairwise-commuting
            # groups concurrently; a conflicting group ends the wave and
            # waits. Compatibility (including shard routes, which read
            # the live schema) is evaluated AFTER the preceding wave has
            # fully executed, so admin barriers can't be read around.
            # A COLD group (executor not pre-planned -> dispatch would
            # compile) never shares a wave with WARM groups: its compile
            # would hold the wave barrier (and under lane locks, its
            # lock) for orders of magnitude longer than a replay. Cold
            # groups may still overlap EACH OTHER — their compiles run
            # concurrently and nobody warm is stalled. One flag check
            # per group, memoized upfront.
            cold = [self._is_cold(g) for g in groups]
            i = 0
            while i < len(groups):
                wave = [groups[i]]
                wave_cold = cold[i]
                i += 1
                while (i < len(groups) and cold[i] == wave_cold
                       and all(self._compatible(groups[i], h)
                               for h in wave)):
                    wave.append(groups[i])
                    i += 1
                await self._dispatch_wave(wave)
