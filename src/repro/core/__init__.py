"""Core: the paper's contribution — a relational cache plane in JAX.

Public API:
    SQLCached      — the daemon (SQL in, device arrays out)
    TableSchema    — schema objects for direct (no-SQL) use
    make_schema    — schema constructor
    table          — functional table ops (jit-composable)
    MemcachedLike  — the opaque-KV baseline from the paper's comparison
    BatchScheduler — cross-connection admission queue / batch dispatcher
    StatementShape — shape_key() grouping descriptor for the scheduler
"""
from repro.core.baseline import MemcachedLike
from repro.core.daemon import Result, SQLCached, StatementShape
from repro.core.schema import ExpiryPolicy, TableSchema, make_schema
from repro.core.scheduler import BatchScheduler

__all__ = [
    "SQLCached",
    "Result",
    "StatementShape",
    "BatchScheduler",
    "TableSchema",
    "ExpiryPolicy",
    "make_schema",
    "MemcachedLike",
]
