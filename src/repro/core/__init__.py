"""Core: the paper's contribution — a relational cache plane in JAX.

Public API:
    SQLCached      — the daemon (SQL in, device arrays out)
    TableSchema    — schema objects for direct (no-SQL) use
    make_schema    — schema constructor
    table          — functional table ops (jit-composable)
    MemcachedLike  — the opaque-KV baseline from the paper's comparison
"""
from repro.core.baseline import MemcachedLike
from repro.core.daemon import Result, SQLCached
from repro.core.schema import ExpiryPolicy, TableSchema, make_schema

__all__ = [
    "SQLCached",
    "Result",
    "TableSchema",
    "ExpiryPolicy",
    "make_schema",
    "MemcachedLike",
]
