"""Host-side serving telemetry: trace spans, histograms, slow log (PR 9).

Observability layer for the serving path.  Everything here is *host only*
— no jax import, no device handle is ever touched — so recording a span
or reading a report can never force a device sync or a
``.block_until_ready()`` on the serving path.

Pieces
------
``Trace``
    Per-statement trace context, stamped at wire receipt
    (``protocol._handle`` on EXEC) and carried through the scheduler to
    the render flush.  ``mark(stage)`` accumulates a monotonic-clock span
    delta into the stage's fixed slot (a clock read plus a float store —
    no allocation); stages on the batched wire path are::

        wire    EXEC receipt -> scheduler admission (frame reassembly, GO wait)
        parse   statement shape derivation at admission
        queue   admission -> start of lane-lock acquisition
        lock    lane/table lock wait
        execute the db.execute/executemany call (includes compile on miss)
        render  response render + lazy-result materialisation at flush

    Attribution fields (``mode``, ``cache``, ``compile_ms``, ``group``,
    ``wave``) are filled in by the dispatch layers via the thread-local
    dispatch context below.

``Counters``
    Lock-guarded counter map with dict-style reads.  This is the atomic
    increment helper the scheduler / server / executor-cache stats use:
    plain ``d[k] += 1`` is a read-modify-write that loses increments
    under concurrent waves; ``Counters.add`` takes a lock per increment
    so totals are exact.

``Histogram``
    Fixed log2-bucketed latency histogram (bucket i counts samples in
    [2^i, 2^(i+1)) microseconds).  Per-bucket increments are plain list
    stores — lock-free — and merging two histograms sums raw buckets,
    so cluster-wide percentiles are computed from merged buckets, never
    percentile-of-percentile.

``Telemetry``
    Per-``SQLCached`` aggregator: per-(table, kind) histograms + stage /
    mode / cache attribution, per-connection rings, and the bounded
    slow-statement ring (``SQLCached(slow_ms=...)`` / ``REPRO_SLOW_MS``).
    Disabled entirely with ``REPRO_TELEMETRY=0`` (``trace()`` returns
    None and the serving path pays nothing but a None check).
    ``finish`` is an O(1) enqueue: the per-shape histogram fold runs in
    a lazy background folder thread (with a fold-on-read backstop at
    report time), keeping even that host work off the serving path.

Dispatch context
----------------
The scheduler runs db calls in worker threads via ``asyncio.to_thread``.
``dispatch_span(traces)`` installs the live traces in a thread-local so
code deep inside the dispatch — ``daemon._run_state`` (exec_mode) and
``execache.ExecEntry`` (hit/miss/compile) — can attribute into them with
``note_mode`` / ``note_exec`` without any plumbing through call
signatures.
"""

from __future__ import annotations

import math
import os
import threading
import time
from collections import deque
from typing import Any, Iterator

from repro.lint import lockorder as LK

__all__ = [
    "Counters",
    "Histogram",
    "Telemetry",
    "Trace",
    "bucket_of",
    "bucket_bounds",
    "current_traces",
    "dispatch_span",
    "merge_reports",
    "note_exec",
    "note_mode",
    "prom",
]

# 2^0 .. 2^(N_BUCKETS-1) microseconds; the last bucket absorbs the tail
# (2^39 us ~ 6.4 days — nothing legitimate lands there).
N_BUCKETS = 40


def bucket_of(us: float) -> int:
    """Log2 bucket index for a latency in microseconds."""
    u = int(us)
    if u < 1:
        return 0
    b = u.bit_length() - 1
    return b if b < N_BUCKETS else N_BUCKETS - 1


def bucket_bounds(i: int) -> tuple[int, int]:
    """[lo, hi) microsecond bounds of bucket ``i``."""
    return (1 << i) if i else 0, 1 << (i + 1)


class Counters:
    """Atomic counter map with dict-style reads.

    Writes (``add`` / ``max`` / ``__setitem__``) take an internal lock so
    concurrent increments from scheduler waves and render threads never
    lose updates; reads use the plain dict protocol so existing
    ``stats["key"]`` / ``dict(stats)`` call sites keep working.
    """

    __slots__ = ("_d", "_lock")

    def __init__(self, initial: dict | None = None):
        self._d: dict[str, Any] = dict(initial or {})
        self._lock = LK.make_lock("telemetry.counters")

    def add(self, key: str, n: int | float = 1) -> None:
        with self._lock:
            self._d[key] = self._d.get(key, 0) + n

    def max(self, key: str, value: int | float) -> None:
        with self._lock:
            if value > self._d.get(key, 0):
                self._d[key] = value

    def bulk(self, pairs) -> None:
        """Apply many (key, delta) increments under ONE lock acquisition
        — keeps per-statement stage attribution off the latency profile."""
        with self._lock:
            d = self._d
            for key, n in pairs:
                d[key] = d.get(key, 0) + n

    def snapshot(self) -> dict:
        with self._lock:
            return dict(self._d)

    # dict-read protocol -------------------------------------------------
    def __getitem__(self, key: str) -> Any:
        return self._d[key]

    def __setitem__(self, key: str, value: Any) -> None:
        with self._lock:
            self._d[key] = value

    def get(self, key: str, default: Any = None) -> Any:
        return self._d.get(key, default)

    def __contains__(self, key: str) -> bool:
        return key in self._d

    def __iter__(self) -> Iterator[str]:
        return iter(self._d)

    def __len__(self) -> int:
        return len(self._d)

    def keys(self):
        return self._d.keys()

    def values(self):
        return self._d.values()

    def items(self):
        return self._d.items()

    def __eq__(self, other: object) -> bool:
        if isinstance(other, Counters):
            return self._d == other._d
        if isinstance(other, dict):
            return self._d == other
        return NotImplemented

    def __repr__(self) -> str:
        return f"Counters({self._d!r})"


class Histogram:
    """Fixed log2-bucketed microsecond histogram.

    ``record`` is a single list-element increment — lock-free and
    sync-free.  Under free-threading two racing increments on the *same*
    bucket may drop one (best effort); exactness guarantees live in
    ``Counters``.  Merging sums raw buckets, which IS exact.
    """

    __slots__ = ("counts",)

    def __init__(self, counts: list[int] | None = None):
        self.counts = list(counts) if counts else [0] * N_BUCKETS

    def record(self, us: float) -> None:
        self.counts[bucket_of(us)] += 1

    @property
    def n(self) -> int:
        return sum(self.counts)

    def percentile(self, q: float) -> float | None:
        """q in [0, 1] -> geometric-midpoint latency of the q-th bucket."""
        n = self.n
        if n == 0:
            return None
        rank = q * n
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank and c:
                lo, hi = bucket_bounds(i)
                return math.sqrt(max(lo, 1) * hi)
        return None

    def merge(self, counts: dict[str, int] | list[int]) -> None:
        """Sum another histogram's raw buckets into this one (exact)."""
        if isinstance(counts, dict):
            for k, c in counts.items():
                self.counts[int(k)] += c
        else:
            for i, c in enumerate(counts):
                self.counts[i] += c

    def sparse(self) -> dict[str, int]:
        """JSON-friendly {bucket-index: count} with empty buckets elided."""
        return {str(i): c for i, c in enumerate(self.counts) if c}


class _ShapeStats:
    """Aggregates for one (table, kind) statement shape."""

    __slots__ = ("hist", "stages", "modes", "cache")

    def __init__(self):
        self.hist = Histogram()
        self.stages = Counters()   # "<stage>.us" totals + "<stage>.n" counts
        self.modes = Counters()    # lane / stacked / mesh / mono
        self.cache = Counters()    # hit / miss / compile / fallback / compile_ms

    def to_dict(self) -> dict:
        h = self.hist
        stages = {}
        for key, val in sorted(self.stages.snapshot().items()):
            stage, _, what = key.rpartition(".")
            ent = stages.setdefault(stage, {})
            if what == "us":
                ent["total_us"] = round(val, 1)
            else:
                ent["count"] = val
        for ent in stages.values():
            if ent.get("count"):
                ent["mean_us"] = round(ent.get("total_us", 0.0) / ent["count"], 1)
        out = {
            "count": h.n,
            "buckets": h.sparse(),
            "stages": stages,
            "modes": self.modes.snapshot(),
            "cache": {k: (round(v, 3) if isinstance(v, float) else v)
                      for k, v in self.cache.snapshot().items()},
        }
        for name, q in (("p50_us", 0.50), ("p99_us", 0.99), ("p999_us", 0.999)):
            p = h.percentile(q)
            if p is not None:
                out[name] = round(p, 1)
        return out


# The serving-path stages, in pipeline order.  Spans live in fixed float
# slots (one per stage) rather than an append-only list: marking a span
# is then a clock read plus a float store — ZERO container allocations —
# which keeps telemetry from raising the GC collection rate (gen2 scans
# of a daemon's object graph are milliseconds, and they land on whatever
# statement is in flight).
STAGES = ("wire", "parse", "queue", "lock", "execute", "render")
_SLOT = {s: "s_" + s for s in STAGES}
_STAGE_KEYS = tuple((s, "s_" + s, s + ".us", s + ".n") for s in STAGES)


class Trace:
    """Per-statement trace context; spans are per-stage delta_us slots."""

    __slots__ = ("t0", "last", "s_wire", "s_parse", "s_queue", "s_lock",
                 "s_execute", "s_render", "sql", "table", "kind",
                 "mode", "cache", "compile_ms", "group", "wave", "error")

    def __init__(self, sql: str | None = None):
        self.t0 = self.last = time.perf_counter()
        self.s_wire = self.s_parse = self.s_queue = 0.0
        self.s_lock = self.s_execute = self.s_render = 0.0
        self.sql = sql
        self.table: str | None = None
        self.kind: str | None = None
        self.mode: str | None = None
        self.cache: str | None = None
        self.compile_ms = 0.0
        self.group: int | None = None
        self.wave: int | None = None
        self.error = False

    def mark(self, stage: str) -> None:
        now = time.perf_counter()
        slot = _SLOT[stage]
        setattr(self, slot, getattr(self, slot) + (now - self.last) * 1e6)
        self.last = now

    @property
    def spans(self) -> list[tuple[str, float]]:
        """(stage, delta_us) pairs for the stages that were marked, in
        pipeline order (built on read — never on the serving path)."""
        return [(s, v) for s, slot, _, _ in _STAGE_KEYS
                if (v := getattr(self, slot))]

    def stage_totals(self) -> dict[str, float]:
        return dict(self.spans)

    def to_dict(self) -> dict:
        d = {
            "sql": self.sql,
            "table": self.table,
            "kind": self.kind,
            "total_us": round((self.last - self.t0) * 1e6, 1),
            "stages": {k: round(v, 1) for k, v in self.stage_totals().items()},
        }
        if self.mode is not None:
            d["mode"] = self.mode
        if self.cache is not None:
            d["cache"] = self.cache
        if self.compile_ms:
            d["compile_ms"] = round(self.compile_ms, 3)
        if self.group is not None:
            d["group"] = self.group
        if self.wave is not None:
            d["wave"] = self.wave
        return d


# ---------------------------------------------------------------------------
# Thread-local dispatch context: lets daemon._run_state / execache attribute
# exec_mode and cache events into the live traces without signature plumbing.

_TLS = threading.local()


class dispatch_span:
    """Install ``traces`` as the ambient dispatch context for this thread.

    A plain class-based context manager (not ``@contextmanager``): it
    sits on the per-statement dispatch path, where the generator
    machinery is measurable overhead.
    """

    __slots__ = ("_traces", "_prev")

    def __init__(self, traces):
        self._traces = [t for t in traces if t is not None] or None

    def __enter__(self):
        self._prev = getattr(_TLS, "traces", None)
        _TLS.traces = self._traces
        return self._traces

    def __exit__(self, exc_type, exc, tb):
        _TLS.traces = self._prev
        return False


def current_traces() -> tuple[Trace, ...] | list[Trace]:
    return getattr(_TLS, "traces", None) or ()


def note_mode(mode: str) -> None:
    """Record the exec_mode (lane/stacked/mesh/mono) on the live traces."""
    for tr in current_traces():
        tr.mode = mode


def note_exec(event: str, compile_ms: float = 0.0) -> None:
    """Record an executor-cache event (hit/compile/fallback) on live traces."""
    for tr in current_traces():
        tr.cache = event
        tr.compile_ms += compile_ms


class Telemetry:
    """Per-daemon telemetry aggregator (one per ``SQLCached``)."""

    RING_SIZE = 256
    SLOW_SIZE = 128
    FOLD_INTERVAL_S = 0.05     # background folder poll period
    FOLD_IDLE_EXIT = 40        # idle polls (~2s) before the folder exits

    def __init__(self, slow_ms: float | None = None,
                 enabled: bool | None = None):
        if enabled is None:
            enabled = os.environ.get("REPRO_TELEMETRY", "1") != "0"
        if slow_ms is None:
            env = os.environ.get("REPRO_SLOW_MS")
            slow_ms = float(env) if env not in (None, "") else None
        self.enabled = enabled
        self.slow_ms = slow_ms
        self.started = time.monotonic()
        self._shapes: dict[tuple[str, str], _ShapeStats] = {}
        self._shapes_lock = LK.make_lock("telemetry.shapes")  # dict insertion only
        self.slow: deque[Trace] = deque(maxlen=self.SLOW_SIZE)
        self._sources: dict[str, Any] = {}     # name -> Counters/dict views
        # finished traces waiting to be folded into the histograms: the
        # serving path only ever pays one deque append; aggregation runs
        # in the background folder thread or at SHOW/report time
        self._pending: deque[Trace] = deque()
        self._fold_lock = LK.make_lock("telemetry.fold")  # one folder at a time
        self._folder: threading.Thread | None = None

    # -- serving path ----------------------------------------------------
    def trace(self, sql: str | None = None) -> Trace | None:
        if not self.enabled:
            return None
        return Trace(sql)

    def ring(self) -> deque:
        """Fresh per-connection ring of finished :class:`Trace` objects
        (rendered to dicts only when read, never on the serving path)."""
        return deque(maxlen=self.RING_SIZE)

    def finish(self, trace: Trace, ring: deque | None = None,
               error: bool = False) -> float:
        """Record a finished trace; returns its total latency in us.

        O(1) on purpose: two deque appends and a thread-liveness check.
        Folding the trace into per-shape histograms/counters costs a few
        microseconds of pure-python work, but doing it inline — even
        after the response bytes are on the wire — showed up as tens of
        microseconds of round-trip p50 on the batched wire path (GIL /
        thread-handoff amplification on the event loop).  So the trace
        is queued and folded OFF the serving path, by a lazy background
        folder thread (started on first use, exits when idle) with a
        fold-on-read backstop in :meth:`report` / :meth:`slow_entries`.
        """
        total_us = (trace.last - trace.t0) * 1e6
        # rings hold the Trace objects themselves; dict rendering happens
        # at SHOW time, never on the serving path
        if ring is not None:
            ring.append(trace)
        if error:
            trace.error = True
        self._pending.append(trace)
        if self._folder is None:
            self._ensure_folder()
        return total_us

    # -- deferred fold ---------------------------------------------------
    def fold(self) -> None:
        """Drain the pending queue into the per-shape aggregates.

        Serialized by ``_fold_lock`` so histogram bucket increments stay
        single-writer (exact), wherever the fold is triggered from.
        """
        if not self._pending:
            return
        with self._fold_lock:
            pending = self._pending
            while pending:
                try:
                    trace = pending.popleft()
                except IndexError:
                    break
                self._fold_one(trace)

    def _fold_one(self, trace: Trace) -> None:
        error = trace.error
        total_us = (trace.last - trace.t0) * 1e6
        key = (trace.table or "-", trace.kind or ("error" if error else "other"))
        ss = self._shapes.get(key)
        if ss is None:
            with self._shapes_lock:
                ss = self._shapes.setdefault(key, _ShapeStats())
        ss.hist.record(total_us)
        stages = ss.stages
        with stages._lock:   # one acquisition for all stage keys
            d = stages._d
            for _, slot, kus, kn in _STAGE_KEYS:
                v = getattr(trace, slot)
                if v:
                    d[kus] = d.get(kus, 0) + v
                    d[kn] = d.get(kn, 0) + 1
        if trace.mode is not None:
            ss.modes.add(trace.mode)
        if trace.cache is not None:
            if trace.compile_ms:
                ss.cache.bulk(((trace.cache, 1),
                               ("compile_ms", trace.compile_ms)))
            else:
                ss.cache.add(trace.cache)
        if error:
            ss.cache.add("errors")
        if self.slow_ms is not None and total_us >= self.slow_ms * 1e3:
            self.slow.append(trace)

    def _ensure_folder(self) -> None:
        with self._shapes_lock:
            if self._folder is None:
                t = threading.Thread(target=self._fold_loop,
                                     name="telemetry-fold", daemon=True)
                self._folder = t
                t.start()

    def _fold_loop(self) -> None:
        idle = 0
        while idle < self.FOLD_IDLE_EXIT:
            time.sleep(self.FOLD_INTERVAL_S)
            if self._pending:
                idle = 0
                self.fold()
            else:
                idle += 1
        # gone quiet: exit and let the next finish() respawn us.  Clear
        # the liveness flag FIRST, then drain once more so a trace that
        # raced in during shutdown is not stranded until the next read.
        self._folder = None
        self.fold()

    def slow_entries(self) -> list[Trace]:
        """Snapshot of the slow-statement ring (folds pending first)."""
        self.fold()
        return list(self.slow)

    # -- daemon-wide roll-up sources (scheduler / server stats) ----------
    def attach(self, name: str, stats) -> None:
        """Register a live stats mapping for the SHOW STATS roll-up."""
        self._sources[name] = stats

    def sources(self) -> dict[str, dict]:
        out = {}
        for name, stats in self._sources.items():
            out[name] = stats.snapshot() if isinstance(stats, Counters) else dict(stats)
        return out

    # -- reporting -------------------------------------------------------
    def uptime_s(self) -> float:
        return round(time.monotonic() - self.started, 3)

    def report(self, table: str | None = None) -> dict:
        self.fold()
        shapes = {}
        for (tbl, kind), ss in sorted(self._shapes.items()):
            if table is not None and tbl != table:
                continue
            shapes[f"{tbl}.{kind}"] = ss.to_dict()
        return {
            "enabled": self.enabled,
            "uptime_s": self.uptime_s(),
            "bucket_base": 2,
            "bucket_unit": "us",
            "shapes": shapes,
            "slow": len(self.slow),
        }


def merge_reports(reports: list[dict]) -> dict:
    """Merge ``Telemetry.report`` dicts from several nodes.

    Buckets, stage totals, mode and cache counts sum exactly; percentiles
    are recomputed from the merged buckets — never averaged.
    """
    shapes: dict[str, dict] = {}
    for rep in reports:
        for name, sd in (rep.get("shapes") or {}).items():
            agg = shapes.get(name)
            if agg is None:
                agg = shapes[name] = {
                    "count": 0, "buckets": {}, "stages": {},
                    "modes": {}, "cache": {},
                }
            agg["count"] += sd.get("count", 0)
            for b, c in (sd.get("buckets") or {}).items():
                agg["buckets"][b] = agg["buckets"].get(b, 0) + c
            for stage, ent in (sd.get("stages") or {}).items():
                tgt = agg["stages"].setdefault(stage, {"total_us": 0.0, "count": 0})
                tgt["total_us"] = round(tgt["total_us"] + ent.get("total_us", 0.0), 1)
                tgt["count"] += ent.get("count", 0)
            for k in ("modes", "cache"):
                for mk, mv in (sd.get(k) or {}).items():
                    agg[k][mk] = round(agg[k].get(mk, 0) + mv, 3) \
                        if isinstance(mv, float) else agg[k].get(mk, 0) + mv
    for agg in shapes.values():
        h = Histogram()
        h.merge(agg["buckets"])
        for name, q in (("p50_us", 0.50), ("p99_us", 0.99), ("p999_us", 0.999)):
            p = h.percentile(q)
            if p is not None:
                agg[name] = round(p, 1)
        for ent in agg["stages"].values():
            if ent["count"]:
                ent["mean_us"] = round(ent["total_us"] / ent["count"], 1)
    return {"nodes": len(reports), "shapes": shapes}


def prom(report: dict, prefix: str = "sqlcached") -> str:
    """Prometheus-style text exposition of a ``Telemetry.report`` dict.

    Buckets are emitted cumulatively with ``le`` upper bounds, matching
    the Prometheus histogram convention; shape and stage become labels.
    """
    lines = [
        f"# HELP {prefix}_uptime_seconds daemon uptime",
        f"# TYPE {prefix}_uptime_seconds gauge",
        f"{prefix}_uptime_seconds {report.get('uptime_s', 0)}",
        f"# TYPE {prefix}_statement_latency_us histogram",
    ]
    for name, sd in sorted((report.get("shapes") or {}).items()):
        lab = f'shape="{name}"'
        buckets = {int(k): v for k, v in (sd.get("buckets") or {}).items()}
        cum = 0
        for i in sorted(buckets):
            cum += buckets[i]
            le = 1 << (i + 1)
            lines.append(
                f'{prefix}_statement_latency_us_bucket{{{lab},le="{le}"}} {cum}')
        lines.append(
            f'{prefix}_statement_latency_us_bucket{{{lab},le="+Inf"}} '
            f'{sd.get("count", 0)}')
        lines.append(f'{prefix}_statement_latency_us_count{{{lab}}} '
                     f'{sd.get("count", 0)}')
        for stage, ent in sorted((sd.get("stages") or {}).items()):
            lines.append(
                f'{prefix}_stage_us_total{{{lab},stage="{stage}"}} '
                f'{ent.get("total_us", 0)}')
        for mode, n in sorted((sd.get("modes") or {}).items()):
            lines.append(f'{prefix}_exec_mode_total{{{lab},mode="{mode}"}} {n}')
    return "\n".join(lines) + "\n"
