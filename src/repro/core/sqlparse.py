"""SQL-subset parser for the cache daemon.

SQLcached's client interface is "an almost complete set of SQL statements"
over a text protocol. We implement the subset that a cache plane needs
(the paper itself notes n-way joins are a performance anti-pattern in a
cache daemon and we exclude them):

  CREATE TABLE t (a INT, b TEXT, INDEX(a), ...,
                  PAYLOAD kv TENSOR(16,2,8,64) BF16)
      [CAPACITY 4096] [MAX_SELECT 256] [TTL 100] [MAX_ROWS 1000]
      [OPS_INTERVAL 64] [SHARDS 4 | SHARDS(4)] [PARTITION BY a]
  INSERT INTO t (a, b) VALUES (?, 'x') [TTL 50]
  SELECT a, b FROM t WHERE a = ? AND b BETWEEN 2 AND 7
      [ORDER BY a [ASC|DESC]] [LIMIT 10]
  SELECT COUNT(*) | MIN(a) | MAX(a) | SUM(a) | AVG(a) FROM t [WHERE ...]
  SELECT PAYLOAD(kv), a FROM t WHERE ...
  UPDATE t SET a = a + 1, TTL = 200 WHERE b = ?
  DELETE FROM t WHERE user_id = ?
  EXPIRE t            -- run automatic expiry now
  FLUSH t             -- drop all rows (the memcached way)
  REINDEX t           -- rebuild t's hash indexes (recovers a stale,
                         i.e. overflowed, index once the duplicate
                         burst that overflowed it is gone)
  DROP TABLE t
  EXPLAIN <stmt>      -- report the chosen query plan (index-probe /
                         fused-scan / generic-scan) without executing
  EXPLAIN t           -- per-shard skew/usage stats (= SHOW STATS t)
  EXPLAIN ANALYZE <stmt>
                      -- execute the statement and report its actual
                         per-stage span timings (wire/parse/queue/lock/
                         execute/render) next to the plan
  SHOW STATS t        -- per-shard live rows + routed-statement counters
  SHOW STATS          -- daemon-wide roll-up: tables, scheduler stats,
                         executor-cache totals, uptime
  SHOW METRICS [t] [FORMAT 'prom']
                      -- serving telemetry report (core/telemetry.py):
                         per-table x per-kind log2 latency histograms,
                         percentiles, stage breakdowns; FORMAT 'prom'
                         emits a Prometheus-style text exposition
  SHOW SLOW           -- bounded ring of slow-statement span trees
                         (SQLCached(slow_ms=...) / REPRO_SLOW_MS)
  ALTER TABLE t RESHARD n
                      -- live re-partition: rebuild the shard pytree at
                         n shards by one bulk device-side re-split (row
                         metadata/TTLs ride along verbatim; n = 1
                         converts back to a monolithic table)
  ALTER TABLE t RETAIN SLOTS 0,3,5 OF 16
                      -- cluster rebalance primitive: keep only the rows
                         whose partition hash lands in the listed slots
                         out of OF slots (same multiplicative hash as
                         SHARDS/RESHARD — shards.shard_of); everything
                         else is dropped in one device-side masked
                         delete. COUNT reports the rows dropped.
  CHECKPOINT t TO 'dir'
                      -- atomic on-disk snapshot of t's device state
                         (checkpoint/store.py format) + the interner
                         strings its TEXT columns reference
  RESTORE t FROM 'dir'
                      -- replace t's contents from a snapshot; TEXT ids
                         are re-interned into THIS daemon's interner
                         (cross-process safe — replica bootstrap),
                         sharded tables re-split rows by hash and hash
                         indexes rebuild
  WARMUP t [LIKE 'SELECT ...']
                      -- pre-plan executors (AOT compile) ahead of
                         traffic: canonical hot shapes per placed lane
                         device, or exactly the quoted statement's shape
                         (core/execache.py). COUNT = new compiles

``REPLICAS r`` in the CREATE option tail declares the table's cluster
replication factor (default 1). The daemon itself stores r as schema
metadata only — mirroring writes to r ring-successor nodes is the
cluster client's job (core/cluster.py); carrying it in the CREATE text
lets every node of a replica group parse the SAME statement verbatim.

``INDEX(col)`` in a CREATE column list declares a device-resident hash
index on an INT/TEXT column; equality WHEREs on it become O(1) bucket
probes (core/planner.py decides, EXPLAIN shows the decision).

``SHARDS n`` (equivalently ``SHARDS(n)``) hash-partitions the table's
rows across ``n`` independent shard tables (core/shards.py), split by a
multiplicative hash of the ``PARTITION BY`` column (defaults to the
first indexed column, else the first INT/TEXT column). An equality WHERE
on the partition column prunes execution to exactly one shard;
everything else fans out across all shards and merges the partials
(EXPLAIN reports the shard route next to the plan).

Statements parse to frozen dataclasses (hashable → usable as static jit
arguments); `?` placeholders become Param nodes so one parse+jit serves
every execution (the prepared-statement cache of the paper).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax.numpy as jnp

from repro.core import predicate as P
from repro.core.schema import SQL_TYPES

_TOKEN_RE = re.compile(
    r"""
    (?P<ws>\s+)
  | (?P<num>\d+\.\d+(?:[eE][+-]?\d+)?|\.\d+|\d+)
  | (?P<str>'(?:[^']|'')*')
  | (?P<name>[A-Za-z_][A-Za-z_0-9]*)
  | (?P<op><=|>=|!=|<>|==|[=<>+\-*/%(),?])
    """,
    re.VERBOSE,
)

_PAYLOAD_DTYPES = {
    "FLOAT": jnp.float32,
    "F32": jnp.float32,
    "BF16": jnp.bfloat16,
    "F16": jnp.float16,
    "INT8": jnp.int8,
    "INT32": jnp.int32,
    "BOOL": jnp.bool_,
}

_AGG_NAMES = ("COUNT", "SUM", "MIN", "MAX", "AVG")


class SQLError(ValueError):
    pass


def tokenize(sql: str) -> list[tuple[str, str]]:
    out, pos = [], 0
    while pos < len(sql):
        m = _TOKEN_RE.match(sql, pos)
        if not m:
            raise SQLError(f"bad token at {sql[pos:pos+20]!r}")
        pos = m.end()
        kind = m.lastgroup
        if kind == "ws":
            continue
        out.append((kind, m.group()))
    out.append(("eof", ""))
    return out


# ---------------------------------------------------------------- statements


@dataclasses.dataclass(frozen=True)
class CreateTable:
    table: str
    columns: tuple[tuple[str, str], ...]  # (name, sql_type)
    payloads: tuple[tuple[str, tuple[int, ...], str], ...]  # (name, shape, dtype)
    capacity: int = 4096
    max_select: int = 1024
    ttl: int = 0
    max_rows: int = 0
    ops_interval: int = 0
    indexes: tuple[str, ...] = ()  # hash-indexed columns (INDEX(col))
    shards: int = 1  # hash-partition count (SHARDS n)
    partition_by: str | None = None  # PARTITION BY col (None = default)
    replicas: int = 1  # cluster replication factor (REPLICAS r)


@dataclasses.dataclass(frozen=True)
class Insert:
    table: str
    columns: tuple[str, ...]
    values: tuple[P.Node, ...]
    ttl: P.Node | None = None


@dataclasses.dataclass(frozen=True)
class Select:
    table: str
    columns: tuple[str, ...]  # () = *
    payloads: tuple[str, ...] = ()
    agg: tuple[str, str | None] | None = None  # (fn, col)
    where: P.Node | None = None
    order_by: str | None = None
    descending: bool = False
    limit: int | None = None


@dataclasses.dataclass(frozen=True)
class Update:
    table: str
    sets: tuple[tuple[str, P.Node], ...]
    where: P.Node | None = None


@dataclasses.dataclass(frozen=True)
class Delete:
    table: str
    where: P.Node | None = None


@dataclasses.dataclass(frozen=True)
class Expire:
    table: str


@dataclasses.dataclass(frozen=True)
class Flush:
    table: str


@dataclasses.dataclass(frozen=True)
class Reindex:
    """REINDEX t: bulk-rebuild the table's hash indexes from the current
    rows, clearing the stale flag when the rebuild fits its buckets."""

    table: str


@dataclasses.dataclass(frozen=True)
class DropTable:
    table: str


@dataclasses.dataclass(frozen=True)
class ShowStats:
    """SHOW STATS t (equivalently ``EXPLAIN t``): per-shard skew report —
    live rows, routed-statement and write counters per execution lane.
    Without a table, the daemon-wide roll-up (tables, scheduler stats,
    executor-cache totals, uptime)."""

    table: str | None = None


@dataclasses.dataclass(frozen=True)
class ShowMetrics:
    """SHOW METRICS [t] [FORMAT 'prom']: the serving-telemetry report —
    per-(table, kind) log2 latency histograms, percentiles and per-stage
    breakdowns (core/telemetry.py). FORMAT 'prom' returns a
    Prometheus-style text exposition (JSON-string-encoded on the wire)."""

    table: str | None = None
    fmt: str | None = None


@dataclasses.dataclass(frozen=True)
class ShowSlow:
    """SHOW SLOW: the bounded ring of slow-statement span trees captured
    by ``SQLCached(slow_ms=...)`` / ``REPRO_SLOW_MS``."""


@dataclasses.dataclass(frozen=True)
class AlterReshard:
    """ALTER TABLE t RESHARD n: live re-partition of a table's rows
    across ``n`` shards (bulk device-side re-split; admin barrier)."""

    table: str
    shards: int


@dataclasses.dataclass(frozen=True)
class AlterRetain:
    """ALTER TABLE t RETAIN SLOTS a,b,c OF m: keep only the rows whose
    partition-column hash (``shards.shard_of(value, m)``) is one of the
    listed slots; drop the rest (one device-side masked delete). The
    cluster tier's rebalance primitive — after a replica bootstraps from
    a full snapshot it RETAINs exactly the key slots the ring assigns
    it, so a node join/leave moves only 1/N of the keyspace."""

    table: str
    slots: tuple[int, ...]
    of: int


@dataclasses.dataclass(frozen=True)
class Checkpoint:
    """CHECKPOINT t TO 'dir': atomic on-disk snapshot of the table's
    device state plus the interner strings its TEXT columns reference
    (checkpoint/store.py format) — the replica-bootstrap source."""

    table: str
    path: str


@dataclasses.dataclass(frozen=True)
class Restore:
    """RESTORE t FROM 'dir': replace the table's contents from a
    CHECKPOINT snapshot. TEXT ids re-intern into this daemon's interner,
    sharded tables re-split rows by hash, hash indexes rebuild — safe
    across processes (replica bootstrap on a different daemon)."""

    table: str
    path: str


@dataclasses.dataclass(frozen=True)
class Warmup:
    """WARMUP t [LIKE '<stmt>']: pre-plan executors ahead of traffic.

    Without LIKE, compiles the table's canonical hot shapes (full-row
    INSERT plus eq-SELECT/DELETE on the partition/index columns) for
    every placed lane device. With LIKE, parses the quoted statement and
    pre-plans exactly that shape. COUNT reports newly compiled
    executables (0 = everything was already planned)."""

    table: str
    like: str | None = None


@dataclasses.dataclass(frozen=True)
class Explain:
    """EXPLAIN <stmt>: report the inner statement's query plan."""

    inner: "Statement"


@dataclasses.dataclass(frozen=True)
class ExplainAnalyze:
    """EXPLAIN ANALYZE <stmt>: execute the inner statement and report
    its measured per-stage span timings next to the plan."""

    inner: "Statement"


Statement = (
    CreateTable | Insert | Select | Update | Delete | Expire | Flush
    | Reindex | DropTable | ShowStats | ShowMetrics | ShowSlow
    | AlterReshard | AlterRetain | Checkpoint | Restore | Warmup
    | Explain | ExplainAnalyze
)


# ------------------------------------------------------------------- parser


class _Parser:
    def __init__(self, sql: str):
        self.toks = tokenize(sql)
        self.i = 0
        self.n_params = 0

    # -- token helpers
    def peek(self):
        return self.toks[self.i]

    def next(self):
        t = self.toks[self.i]
        self.i += 1
        return t

    def accept_kw(self, *kws) -> str | None:
        kind, val = self.peek()
        if kind == "name" and val.upper() in kws:
            self.next()
            return val.upper()
        return None

    def expect_kw(self, *kws) -> str:
        got = self.accept_kw(*kws)
        if got is None:
            raise SQLError(f"expected {'/'.join(kws)}, got {self.peek()[1]!r}")
        return got

    def accept_op(self, *ops) -> str | None:
        kind, val = self.peek()
        if kind == "op" and val in ops:
            self.next()
            return val
        return None

    def expect_op(self, op: str):
        if not self.accept_op(op):
            raise SQLError(f"expected {op!r}, got {self.peek()[1]!r}")

    def name(self) -> str:
        kind, val = self.next()
        if kind != "name":
            raise SQLError(f"expected identifier, got {val!r}")
        return val

    def integer(self) -> int:
        kind, val = self.next()
        if kind != "num" or "." in val:
            raise SQLError(f"expected integer, got {val!r}")
        return int(val)

    # -- expressions
    def expr(self) -> P.Node:
        return self._or()

    def _or(self) -> P.Node:
        node = self._and()
        while self.accept_kw("OR"):
            node = P.Or(node, self._and())
        return node

    def _and(self) -> P.Node:
        node = self._not()
        while self.accept_kw("AND"):
            node = P.And(node, self._not())
        return node

    def _not(self) -> P.Node:
        if self.accept_kw("NOT"):
            return P.Not(self._not())
        return self._cmp()

    def _cmp(self) -> P.Node:
        node = self._add()
        op = self.accept_op("=", "==", "!=", "<>", "<", "<=", ">", ">=")
        if op:
            return P.BinOp(op, node, self._add())
        if self.accept_kw("BETWEEN"):
            lo = self._add()
            self.expect_kw("AND")
            return P.Between(node, lo, self._add())
        if self.accept_kw("IN"):
            self.expect_op("(")
            items = [self.expr()]
            while self.accept_op(","):
                items.append(self.expr())
            self.expect_op(")")
            return P.InList(node, tuple(items))
        return node

    def _add(self) -> P.Node:
        node = self._mul()
        while True:
            op = self.accept_op("+", "-")
            if not op:
                return node
            node = P.BinOp(op, node, self._mul())

    def _mul(self) -> P.Node:
        node = self._unary()
        while True:
            op = self.accept_op("*", "/", "%")
            if not op:
                return node
            node = P.BinOp(op, node, self._unary())

    def _unary(self) -> P.Node:
        if self.accept_op("-"):
            return P.BinOp("-", P.Const(0), self._unary())
        return self._primary()

    def _primary(self) -> P.Node:
        kind, val = self.peek()
        if kind == "num":
            self.next()
            return P.Const(float(val) if "." in val or "e" in val.lower() else int(val))
        if kind == "str":
            self.next()
            return P.Const(val[1:-1].replace("''", "'"))
        if kind == "op" and val == "?":
            self.next()
            node = P.Param(self.n_params)
            self.n_params += 1
            return node
        if kind == "op" and val == "(":
            self.next()
            node = self.expr()
            self.expect_op(")")
            return node
        if kind == "name":
            nm = self.name()
            if self.accept_op("("):
                args = []
                if not self.accept_op(")"):
                    args.append(self.expr())
                    while self.accept_op(","):
                        args.append(self.expr())
                    self.expect_op(")")
                return P.Func(nm, tuple(args))
            return P.Col(nm)
        raise SQLError(f"unexpected token {val!r}")

    _STMT_KWS = ("CREATE", "INSERT", "SELECT", "UPDATE", "DELETE",
                 "EXPIRE", "FLUSH", "REINDEX", "DROP", "SHOW", "ALTER",
                 "CHECKPOINT", "RESTORE", "WARMUP")

    # -- statements
    def statement(self) -> Statement:
        explain = self.accept_kw("EXPLAIN") is not None
        analyze = False
        if explain:
            # ANALYZE must be consumed before the EXPLAIN <table> branch
            # or "EXPLAIN ANALYZE x" would parse as ShowStats("ANALYZE")
            analyze = self.accept_kw("ANALYZE") is not None
            if not analyze:
                kind, val = self.peek()
                if kind == "name" and val.upper() not in self._STMT_KWS:
                    # EXPLAIN <table>: the per-shard stats report
                    stmt = ShowStats(self.name())
                    if self.peek()[0] != "eof":
                        raise SQLError(
                            f"trailing tokens: {self.peek()[1]!r}")
                    return stmt
        kw = self.expect_kw(*self._STMT_KWS)
        fn = getattr(self, f"_stmt_{kw.lower()}")
        stmt = fn()
        if self.peek()[0] != "eof":
            raise SQLError(f"trailing tokens: {self.peek()[1]!r}")
        if analyze:
            return ExplainAnalyze(stmt)
        return Explain(stmt) if explain else stmt

    def _stmt_create(self) -> CreateTable:
        self.expect_kw("TABLE")
        table = self.name()
        self.expect_op("(")
        columns, payloads, indexes = [], [], []
        while True:
            nk, nv = self.peek()
            follows_paren = (nk == "name" and nv.upper() == "INDEX"
                             and self.toks[self.i + 1][1] == "(")
            if follows_paren and self.accept_kw("INDEX"):
                self.expect_op("(")
                indexes.append(self.name())
                self.expect_op(")")
            elif self.accept_kw("PAYLOAD"):
                pname = self.name()
                self.expect_kw("TENSOR")
                self.expect_op("(")
                shape = [self.integer()]
                while self.accept_op(","):
                    shape.append(self.integer())
                self.expect_op(")")
                dt = "FLOAT"
                kind, val = self.peek()
                if kind == "name" and val.upper() in _PAYLOAD_DTYPES:
                    dt = self.next()[1].upper()
                payloads.append((pname, tuple(shape), dt))
            else:
                cname = self.name()
                ctype = self.name().upper()
                if ctype not in SQL_TYPES:
                    raise SQLError(f"unknown type {ctype!r}")
                columns.append((cname, ctype))
            if not self.accept_op(","):
                break
        self.expect_op(")")
        opts = {"capacity": 4096, "max_select": 1024, "ttl": 0, "max_rows": 0,
                "ops_interval": 0, "shards": 1, "replicas": 1}
        partition_by = None
        while True:
            kw = self.accept_kw("CAPACITY", "MAX_SELECT", "TTL", "MAX_ROWS",
                                "OPS_INTERVAL", "SHARDS", "PARTITION",
                                "REPLICAS")
            if not kw:
                break
            if kw == "PARTITION":
                self.expect_kw("BY")
                partition_by = self.name()
            elif kw == "SHARDS" and self.accept_op("("):
                opts["shards"] = self.integer()  # SHARDS(n) form
                self.expect_op(")")
            else:
                opts[kw.lower()] = self.integer()
        if opts["shards"] < 1:
            raise SQLError("SHARDS must be >= 1")
        if opts["replicas"] < 1:
            raise SQLError("REPLICAS must be >= 1")
        return CreateTable(table, tuple(columns), tuple(payloads),
                           indexes=tuple(indexes), partition_by=partition_by,
                           **opts)

    def _stmt_insert(self) -> Insert:
        self.expect_kw("INTO")
        table = self.name()
        cols = []
        if self.accept_op("("):
            cols.append(self.name())
            while self.accept_op(","):
                cols.append(self.name())
            self.expect_op(")")
        self.expect_kw("VALUES")
        self.expect_op("(")
        vals = [self.expr()]
        while self.accept_op(","):
            vals.append(self.expr())
        self.expect_op(")")
        ttl = None
        if self.accept_kw("TTL"):
            ttl = self.expr()
        return Insert(table, tuple(cols), tuple(vals), ttl)

    def _stmt_select(self) -> Select:
        columns: list[str] = []
        payloads: list[str] = []
        agg = None
        if self.accept_op("*"):
            pass
        else:
            while True:
                kind, val = self.peek()
                up = val.upper() if kind == "name" else ""
                if up in _AGG_NAMES:
                    self.next()
                    self.expect_op("(")
                    if self.accept_op("*"):
                        agg = (up, None)
                    else:
                        agg = (up, self.name())
                    self.expect_op(")")
                elif up == "PAYLOAD":
                    self.next()
                    self.expect_op("(")
                    payloads.append(self.name())
                    self.expect_op(")")
                else:
                    columns.append(self.name())
                if not self.accept_op(","):
                    break
        self.expect_kw("FROM")
        table = self.name()
        where = self.expr() if self.accept_kw("WHERE") else None
        order_by, desc = None, False
        if self.accept_kw("ORDER"):
            self.expect_kw("BY")
            order_by = self.name()
            if self.accept_kw("DESC"):
                desc = True
            else:
                self.accept_kw("ASC")
        limit = self.integer() if self.accept_kw("LIMIT") else None
        return Select(table, tuple(columns), tuple(payloads), agg, where,
                      order_by, desc, limit)

    def _stmt_update(self) -> Update:
        table = self.name()
        self.expect_kw("SET")
        sets = []
        while True:
            col = self.name()
            self.expect_op("=")
            sets.append((col, self.expr()))
            if not self.accept_op(","):
                break
        where = self.expr() if self.accept_kw("WHERE") else None
        return Update(table, tuple(sets), where)

    def _stmt_delete(self) -> Delete:
        self.expect_kw("FROM")
        table = self.name()
        where = self.expr() if self.accept_kw("WHERE") else None
        return Delete(table, where)

    def _stmt_expire(self) -> Expire:
        return Expire(self.name())

    def _stmt_flush(self) -> Flush:
        return Flush(self.name())

    def _stmt_reindex(self) -> Reindex:
        return Reindex(self.name())

    def _stmt_drop(self) -> DropTable:
        self.expect_kw("TABLE")
        return DropTable(self.name())

    def _stmt_show(self) -> "ShowStats | ShowMetrics | ShowSlow":
        kw = self.expect_kw("STATS", "METRICS", "SLOW")
        if kw == "SLOW":
            return ShowSlow()
        if kw == "METRICS":
            table = None
            kind, val = self.peek()
            if kind == "name" and val.upper() != "FORMAT":
                table = self.name()
            fmt = None
            if self.accept_kw("FORMAT"):
                fmt = self._string().lower()
                if fmt not in ("json", "prom"):
                    raise SQLError(f"unknown METRICS format {fmt!r}")
            return ShowMetrics(table, fmt)
        if self.peek()[0] == "name":
            return ShowStats(self.name())
        return ShowStats(None)

    def _stmt_alter(self) -> "AlterReshard | AlterRetain":
        self.expect_kw("TABLE")
        table = self.name()
        kw = self.expect_kw("RESHARD", "RETAIN")
        if kw == "RESHARD":
            n = self.integer()
            if n < 1:
                raise SQLError("RESHARD must be >= 1")
            return AlterReshard(table, n)
        self.expect_kw("SLOTS")
        slots = [self.integer()]
        while self.accept_op(","):
            slots.append(self.integer())
        self.expect_kw("OF")
        m = self.integer()
        if m < 1:
            raise SQLError("RETAIN ... OF m: m must be >= 1")
        if any(s < 0 or s >= m for s in slots):
            raise SQLError(f"RETAIN slot out of range [0, {m})")
        return AlterRetain(table, tuple(sorted(set(slots))), m)

    def _string(self) -> str:
        kind, val = self.next()
        if kind != "str":
            raise SQLError(f"expected string literal, got {val!r}")
        return val[1:-1].replace("''", "'")

    def _stmt_checkpoint(self) -> Checkpoint:
        table = self.name()
        self.expect_kw("TO")
        return Checkpoint(table, self._string())

    def _stmt_restore(self) -> Restore:
        table = self.name()
        self.expect_kw("FROM")
        return Restore(table, self._string())

    def _stmt_warmup(self) -> Warmup:
        table = self.name()
        like = self._string() if self.accept_kw("LIKE") else None
        return Warmup(table, like)


def parse(sql: str) -> Statement:
    return _Parser(sql).statement()
