"""Table schemas for the device-resident relational cache (SQLcached on TPU).

A table is a fixed-capacity struct-of-arrays: scalar metadata *columns*
(int/float/bool; TEXT is interned host-side to int64 ids) plus optional
tensor *payloads* — one fixed-shape tensor per row, stored in a pool array
``[capacity, *shape]``. Payloads are the paper's "complex data without
serialization": typed device tensors (KV blocks, SSM states, encoder
outputs) instead of pickled blobs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax.numpy as jnp
import numpy as np

# SQL type name -> numpy dtype. TEXT is stored as an interned int64 id.
SQL_TYPES: dict[str, Any] = {
    "INT": np.int32,
    "INTEGER": np.int32,
    "BIGINT": np.int64,
    "FLOAT": np.float32,
    "REAL": np.float32,
    "DOUBLE": np.float64,
    "BOOL": np.bool_,
    "BOOLEAN": np.bool_,
    "TEXT": np.int32,  # interned string id (host-side interner; <2^31 ids)
}

# Columns maintained automatically on every table (the paper's expiry
# metadata): insertion timestamp, last access, per-row ttl (0 = no ttl).
RESERVED_COLUMNS = ("_created", "_accessed", "_ttl")


@dataclasses.dataclass(frozen=True)
class ColumnSpec:
    name: str
    sql_type: str  # key into SQL_TYPES
    is_text: bool = False

    @property
    def dtype(self):
        return SQL_TYPES[self.sql_type.upper()]


@dataclasses.dataclass(frozen=True)
class PayloadSpec:
    """A fixed-shape tensor attached to each row (pool column)."""

    name: str
    shape: tuple[int, ...]
    dtype: Any = jnp.float32


@dataclasses.dataclass(frozen=True)
class ExpiryPolicy:
    """The paper's three automatic expiry conditions (§4.3).

    - ``ttl``: default data-age limit in logical-clock ticks (0 = none);
      per-row ``_ttl`` overrides when nonzero.
    - ``max_rows``: table size cap; oldest rows evicted beyond it (0 = none).
    - ``ops_interval``: run automatic expiry every N cache operations
      (0 = only when explicitly asked).
    """

    ttl: int = 0
    max_rows: int = 0
    ops_interval: int = 0


@dataclasses.dataclass(frozen=True)
class TableSchema:
    name: str
    columns: tuple[ColumnSpec, ...]
    payloads: tuple[PayloadSpec, ...] = ()
    capacity: int = 4096
    max_select: int = 1024  # fixed upper bound on rows a SELECT returns
    expiry: ExpiryPolicy = ExpiryPolicy()
    # columns carrying a device-resident hash index (kernels/hashidx):
    # int32-typed only (INT, or TEXT via the interner). Equality lookups
    # on these lower to an O(1) bucket probe instead of a full scan.
    indexes: tuple[str, ...] = ()
    # Horizontal partitioning (core/shards.py): ``shards > 1`` hash-
    # partitions the rows across that many independent shard tables, each
    # with its own validity mask / relscan tiles / hash indexes, by a
    # multiplicative hash of ``partition_by`` (an int32 column — INT, or
    # TEXT via the interner; defaults to the first indexed column, else
    # the first int32 column). ``capacity`` stays the LOGICAL total; each
    # shard holds ceil(capacity / shards) rows. The shard count is NOT
    # fixed for the table's lifetime: ``ALTER TABLE t RESHARD n``
    # re-partitions live via ``dataclasses.replace(schema, shards=n)``
    # (this validation re-runs; ``partition_by`` survives a RESHARD 1
    # round trip so the table can be re-partitioned later).
    shards: int = 1
    partition_by: str | None = None
    # Cluster replication factor (``REPLICAS r``): metadata only at this
    # layer — the daemon stores and reports it, the cluster client
    # (core/cluster.py) mirrors writes to r ring-successor nodes.
    replicas: int = 1

    def __post_init__(self):
        names = [c.name for c in self.columns] + [p.name for p in self.payloads]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate column names in table {self.name!r}")
        for r in RESERVED_COLUMNS:
            if r in names:
                raise ValueError(f"{r} is a reserved column name")
        if self.max_select > self.capacity:
            object.__setattr__(self, "max_select", self.capacity)
        for ix in self.indexes:
            if np.dtype(self.column(ix).dtype) != np.int32:
                raise ValueError(
                    f"index on {ix!r}: only int32 (INT/TEXT) columns are "
                    f"indexable")
        if len(set(self.indexes)) != len(self.indexes):
            raise ValueError(f"duplicate index in table {self.name!r}")
        if self.shards < 1:
            raise ValueError(f"table {self.name!r}: SHARDS must be >= 1")
        if self.replicas < 1:
            raise ValueError(f"table {self.name!r}: REPLICAS must be >= 1")
        if self.shards > 1:
            if self.partition_by is None:
                object.__setattr__(self, "partition_by",
                                   self._default_partition_column())
            if np.dtype(self.column(self.partition_by).dtype) != np.int32:
                raise ValueError(
                    f"PARTITION BY {self.partition_by!r}: only int32 "
                    f"(INT/TEXT) columns are partitionable")
        elif self.partition_by is not None:
            if not self.has_column(self.partition_by):
                raise KeyError(f"no column {self.partition_by!r} in table "
                               f"{self.name!r}")

    def _default_partition_column(self) -> str:
        if self.indexes:
            return self.indexes[0]
        for c in self.columns:
            if np.dtype(c.dtype) == np.int32:
                return c.name
        raise ValueError(
            f"table {self.name!r}: SHARDS needs an int32 (INT/TEXT) column "
            f"to PARTITION BY")

    @property
    def column_names(self) -> tuple[str, ...]:
        return tuple(c.name for c in self.columns)

    def column(self, name: str) -> ColumnSpec:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(f"no column {name!r} in table {self.name!r}")

    def payload(self, name: str) -> PayloadSpec:
        for p in self.payloads:
            if p.name == name:
                return p
        raise KeyError(f"no payload {name!r} in table {self.name!r}")

    def has_column(self, name: str) -> bool:
        return any(c.name == name for c in self.columns)

    def text_columns(self) -> tuple[str, ...]:
        return tuple(c.name for c in self.columns if c.is_text)


def validate_row_values(schema: TableSchema, values: Mapping[str, Any]) -> None:
    for k in values:
        if not schema.has_column(k):
            raise KeyError(f"unknown column {k!r} for table {schema.name!r}")


def make_schema(
    name: str,
    columns: Sequence[tuple[str, str]],
    payloads: Sequence[tuple[str, tuple[int, ...], Any]] = (),
    capacity: int = 4096,
    max_select: int = 1024,
    expiry: ExpiryPolicy = ExpiryPolicy(),
    indexes: Sequence[str] = (),
    shards: int = 1,
    partition_by: str | None = None,
    replicas: int = 1,
) -> TableSchema:
    cols = tuple(
        ColumnSpec(n, t, is_text=(t.upper() == "TEXT")) for n, t in columns
    )
    pls = tuple(PayloadSpec(n, tuple(s), d) for n, s, d in payloads)
    return TableSchema(name, cols, pls, capacity, max_select, expiry,
                       tuple(indexes), shards, partition_by, replicas)
