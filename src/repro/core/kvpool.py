"""KV-block pool: the paper's cache table specialized for transformer KV.

This is SQLcached's central claim applied to serving: KV blocks are
*complex data* (typed tensors) whose metadata (sequence, user, position,
prefix hash, access time, ttl) lives in queryable columns. One row = one
block of ``block_size`` token positions across *all* layers, so a single
page table serves the whole model.

Table schema (built by :func:`kv_schema`):

    columns:  slot INT         -- batch slot of the owning request
              seq_id INT       -- request/sequence id
              user_id INT      -- session owner (per-user expiry, §4.3)
              pos_block INT    -- block index within the sequence
              prefix_hash INT  -- rolling hash of tokens up to block end
    payload:  kv TENSOR(layers, 2, block, kv_heads, head_dim)

Fine-grained expiry — the Table 2 operations — are plain SQL against
this table::

    DELETE FROM kv WHERE seq_id = ?     -- finish one request   (~"one page")
    DELETE FROM kv WHERE user_id = ?    -- end one user session (~"one user")
    FLUSH kv                            -- the memcached way

The functions here are pure and jit-composable; the serving engine
threads the table state through its scheduler ticks.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import predicate as P
from repro.core import table as T
from repro.core.schema import ExpiryPolicy, TableSchema, make_schema

KV_COLUMNS = (
    ("slot", "INT"),
    ("seq_id", "INT"),
    ("user_id", "INT"),
    ("pos_block", "INT"),
    ("prefix_hash", "INT"),
)


def kv_schema(
    *,
    layers: int,
    block_size: int,
    kv_heads: int,
    head_dim: int,
    capacity: int,
    dtype: Any = jnp.bfloat16,
    name: str = "kv",
    expiry: ExpiryPolicy = ExpiryPolicy(),
    max_select: int = 256,
    indexes: tuple[str, ...] = (),
) -> TableSchema:
    """``indexes`` (e.g. ``("seq_id", "user_id")``) puts a device-resident
    hash index on the named columns, turning the Table 2 fine-grained
    expiry shapes (``DELETE ... WHERE seq_id = ?``) into O(1) bucket
    probes at the cost of per-insert index maintenance — worth it once
    the pool outgrows a few thousand blocks."""
    payload = ("kv", (layers, 2, block_size, kv_heads, head_dim), dtype)
    return make_schema(
        name, list(KV_COLUMNS), [payload],
        capacity=capacity, max_select=max_select, expiry=expiry,
        indexes=indexes,
    )


def init_pool(schema: TableSchema) -> dict:
    return T.init_state(schema)


def append_blocks(
    schema: TableSchema,
    state: dict,
    *,
    slot: jax.Array,        # [n] int32
    seq_id: jax.Array,      # [n]
    user_id: jax.Array,     # [n]
    pos_block: jax.Array,   # [n]
    prefix_hash: jax.Array, # [n]
    kv: jax.Array,          # [n, layers, 2, block, kv_heads, head_dim]
    row_mask: jax.Array | None = None,
    ttl: int | jax.Array = 0,
):
    """Insert ``n`` KV blocks; returns (state, slots, evicted)."""
    values = {
        "slot": slot, "seq_id": seq_id, "user_id": user_id,
        "pos_block": pos_block, "prefix_hash": prefix_hash,
    }
    return T.insert(schema, state, values, {"kv": kv}, row_mask, ttl)


def page_table(schema: TableSchema, state: dict, *, max_slots: int,
               max_blocks: int) -> jax.Array:
    """Materialize [max_slots, max_blocks] page table of pool row ids.

    Entry (s, b) = row index of the valid block with slot==s, pos_block==b;
    missing entries hold ``capacity`` (the sentinel the paged-attention
    kernel masks on). One O(capacity) scatter — the TPU-native 'index'.
    """
    cap = schema.capacity
    slot = state["cols"]["slot"]
    pos = state["cols"]["pos_block"]
    valid = state["valid"]
    in_range = valid & (slot >= 0) & (slot < max_slots) & (pos >= 0) & (pos < max_blocks)
    s = jnp.where(in_range, slot, max_slots)  # out-of-range -> dropped
    b = jnp.where(in_range, pos, 0)
    pt = jnp.full((max_slots + 1, max_blocks), cap, dtype=jnp.int32)
    pt = pt.at[s, b].set(jnp.arange(cap, dtype=jnp.int32), mode="drop")
    return pt[:max_slots]


def seq_lengths(schema: TableSchema, state: dict, *, max_slots: int,
                block_size: int) -> jax.Array:
    """Per-slot cached length in tokens = (#blocks) * block_size."""
    cap = schema.capacity
    slot = state["cols"]["slot"]
    valid = state["valid"]
    s = jnp.where(valid & (slot >= 0) & (slot < max_slots), slot, max_slots)
    counts = jnp.zeros((max_slots + 1,), dtype=jnp.int32)
    counts = counts.at[s].add(1, mode="drop")
    return counts[:max_slots] * block_size


# ------------------------------------------------- incremental maintenance
#
# Rebuilding the page table / length vector is one O(capacity) scatter per
# tick. The serving engine instead keeps both *incrementally*: inserts and
# deletes report the row ids they touched (T.insert slots / Result.row_ids
# from the fused DELETE path), and these updates scatter only O(k) entries.
# The full rebuilds above stay as the bootstrap/fallback (and the parity
# oracle in tests).


def _on_state_device(state: dict, *arrs):
    """Colocate small result handles with ``state``'s device.

    The incremental updates combine handles the daemon's executors
    returned (``Result.row_ids_device`` / ``present_device``) with the
    table state; under mesh placement (PR 7) a pruned statement's
    handles live on the route's OWN device while a flattened sharded
    state lives on the default device, and jax refuses mixed committed
    devices. Tracers (the jit-composable use) and uncommitted/multi-
    device arrays pass through untouched."""
    dev = None
    for leaf in jax.tree.leaves(state):
        if isinstance(leaf, jax.Array) and not isinstance(leaf, jax.core.Tracer):
            devs = leaf.devices()
            if len(devs) == 1:
                dev = next(iter(devs))
            break
    if dev is None:
        return arrs
    return tuple(
        jax.device_put(a, dev)
        if (isinstance(a, jax.Array) and not isinstance(a, jax.core.Tracer)
            and a.devices() != {dev})
        else a
        for a in arrs)


def _pt_coords(state: dict, row_ids, ok, *, max_slots: int, max_blocks: int):
    slot = state["cols"]["slot"][row_ids]
    pos = state["cols"]["pos_block"][row_ids]
    ok = ok & (slot >= 0) & (slot < max_slots) & (pos >= 0) & (pos < max_blocks)
    return jnp.where(ok, slot, max_slots), jnp.where(ok, pos, 0)


def page_table_insert(
    schema: TableSchema, state: dict, pt: jax.Array, row_ids: jax.Array,
    evicted: jax.Array, *, max_slots: int, max_blocks: int,
) -> jax.Array:
    """Incremental page-table update after inserting ``row_ids`` (the slots
    T.insert returned): O(k) scatter of the new (slot, pos_block) entries.

    ``evicted`` is the insert's eviction count (a device scalar — no host
    sync). When the allocator LRU-evicted live rows their old coordinates
    are unrecoverable from the new state, so a device-side ``lax.cond``
    falls back to the full O(capacity) rebuild — the steady-state serving
    path (deletes precede reuse) never takes it.
    """
    pt, row_ids, evicted = _on_state_device(state, pt, row_ids, evicted)

    def inc(_):
        ok = jnp.ones(row_ids.shape, dtype=bool)
        s, b = _pt_coords(state, row_ids, ok,
                          max_slots=max_slots, max_blocks=max_blocks)
        return pt.at[s, b].set(row_ids.astype(jnp.int32), mode="drop")

    def rebuild(_):
        return page_table(schema, state, max_slots=max_slots,
                          max_blocks=max_blocks)

    return jax.lax.cond(evicted > 0, rebuild, inc, None)


def page_table_delete(
    schema: TableSchema, state: dict, pt: jax.Array, row_ids: jax.Array,
    present: jax.Array, *, max_slots: int, max_blocks: int,
) -> jax.Array:
    """Incremental page-table update after a DELETE: clear the entries of
    the deleted ``row_ids`` (``present`` masks the padded tail). DELETE only
    flips validity bits, so the rows' coordinates are still readable."""
    pt, row_ids, present = _on_state_device(state, pt, row_ids, present)
    s, b = _pt_coords(state, row_ids, present,
                      max_slots=max_slots, max_blocks=max_blocks)
    return pt.at[s, b].set(schema.capacity, mode="drop")


def seq_lengths_insert(
    schema: TableSchema, state: dict, lengths: jax.Array,
    row_ids: jax.Array, evicted: jax.Array, *, block_size: int,
    max_slots: int,
) -> jax.Array:
    """Incremental per-slot cached-length update after inserting rows.
    Same eviction contract as :func:`page_table_insert`: O(k) adds in the
    steady state, device-side fallback to the full recount on eviction."""
    lengths, row_ids, evicted = _on_state_device(
        state, lengths, row_ids, evicted)

    def inc(_):
        slot = state["cols"]["slot"][row_ids]
        ok = (slot >= 0) & (slot < max_slots)
        s = jnp.where(ok, slot, max_slots)
        padded = jnp.concatenate([lengths, jnp.zeros((1,), lengths.dtype)])
        padded = padded.at[s].add(jnp.where(ok, block_size, 0), mode="drop")
        return padded[:max_slots]

    def rebuild(_):
        return seq_lengths(schema, state, max_slots=max_slots,
                           block_size=block_size)

    return jax.lax.cond(evicted > 0, rebuild, inc, None)


def seq_lengths_delete(
    schema: TableSchema, state: dict, lengths: jax.Array,
    row_ids: jax.Array, present: jax.Array, *, block_size: int,
    max_slots: int,
) -> jax.Array:
    """Incremental per-slot cached-length update after a DELETE."""
    lengths, row_ids, present = _on_state_device(
        state, lengths, row_ids, present)
    slot = state["cols"]["slot"][row_ids]
    ok = present & (slot >= 0) & (slot < max_slots)
    s = jnp.where(ok, slot, max_slots)
    padded = jnp.concatenate([lengths, jnp.zeros((1,), lengths.dtype)])
    padded = padded.at[s].add(jnp.where(ok, -block_size, 0), mode="drop")
    return padded[:max_slots]


def gather_blocks(state: dict, pages: jax.Array) -> jax.Array:
    """Gather KV payloads through a page table. pages: [slots, blocks] row
    ids (sentinel = capacity → zeros). Returns
    [slots, blocks, layers, 2, block, kv_heads, head_dim]."""
    pool = state["payloads"]["kv"]
    cap = pool.shape[0]
    safe = jnp.minimum(pages, cap - 1)
    out = pool[safe]
    mask = (pages < cap)[..., None, None, None, None, None]
    return jnp.where(mask, out, jnp.zeros((), dtype=pool.dtype))


def delete_seq(schema: TableSchema, state: dict, seq_id) -> tuple[dict, jax.Array]:
    """Fine-grained expiry: one request's blocks (paper's 'single page')."""
    return T.delete(schema, state, P.BinOp("=", P.Col("seq_id"), P.Param(0)),
                    (seq_id,))


def delete_user(schema: TableSchema, state: dict, user_id) -> tuple[dict, jax.Array]:
    """Fine-grained expiry: one user's sessions (paper's 'single user')."""
    return T.delete(schema, state, P.BinOp("=", P.Col("user_id"), P.Param(0)),
                    (user_id,))


def find_prefix(schema: TableSchema, state: dict, prefix_hash,
                *, limit: int = 64):
    """Prefix-cache lookup: all blocks whose prefix hash matches — the
    paper's 'retrieval by complex criteria' reused as transformer prefix
    caching. Returns (state, result) with row ids + pos_block columns."""
    where = P.BinOp("=", P.Col("prefix_hash"), P.Param(0))
    return T.select(schema, state, where, (prefix_hash,),
                    columns=("pos_block", "seq_id"), limit=limit)


def rolling_prefix_hashes(tokens: jax.Array, block_size: int) -> jax.Array:
    """Deterministic rolling hash per block boundary (host or device).

    tokens: [seq] int32 -> [seq // block_size] int32 hashes. Uses a
    multiplicative rolling hash folded per block; stable across runs.
    """
    seq = tokens.shape[0]
    nblk = seq // block_size
    tok = tokens[: nblk * block_size].reshape(nblk, block_size).astype(jnp.uint32)

    def block_fold(carry, blk):
        h = carry
        def tok_fold(h, t):
            return h * jnp.uint32(1000003) + t + jnp.uint32(1), None
        h, _ = jax.lax.scan(tok_fold, h, blk)
        return h, h

    _, hashes = jax.lax.scan(block_fold, jnp.uint32(2166136261), tok)
    # map into positive int32 range (column dtype)
    return (hashes & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)
