"""RelTable: a fixed-capacity, device-resident relational cache table.

The TPU-native reimagining of SQLcached's SQLite-backed store (DESIGN.md §2):

- storage is struct-of-arrays with a validity bitmap — no pointers, no
  B-trees; every query is a vectorized masked scan (VPU-friendly, jit-able
  with fixed shapes);
- every operation is a *pure function* ``(state, ...) -> (state, result)``
  so the daemon can jit + donate it and thread it through pjit programs;
- slot allocation unifies the free list with LRU eviction: one ``top_k``
  over ``where(valid, _accessed, -1)`` picks invalid rows first, then the
  least-recently-used valid rows (the paper's "number of records" expiry
  becomes the allocator itself);
- a logical clock stamps ``_created`` / ``_accessed``; the paper's three
  automatic expiry conditions (age / row count / op count, §4.3) are
  implemented in :func:`expire`.

Row results of SELECT are fixed-size (``schema.max_select``) with an exact
``count`` — the host slices; payload gathers stay on device for zero-copy
hand-off to compute (e.g. paged attention reading KV blocks).
"""
from __future__ import annotations

import functools
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import predicate as P
from repro.core.schema import RESERVED_COLUMNS, SQL_TYPES, TableSchema
from repro.kernels import ops as OPS

CLOCK_DTYPE = jnp.int64 if jax.config.read("jax_enable_x64") else jnp.int32
# NOTE: we keep clocks in int32 unless x64 is enabled; the daemon widens by
# running with jax_enable_x64 when available. 2^31 ops is plenty for tests.


def init_state(schema: TableSchema) -> dict:
    cap = schema.capacity
    cols = {c.name: jnp.zeros((cap,), dtype=c.dtype) for c in schema.columns}
    for r in RESERVED_COLUMNS:
        cols[r] = jnp.zeros((cap,), dtype=jnp.int32)
    payloads = {
        p.name: jnp.zeros((cap,) + p.shape, dtype=p.dtype) for p in schema.payloads
    }
    return {
        "cols": cols,
        "payloads": payloads,
        "valid": jnp.zeros((cap,), dtype=bool),
        "clock": jnp.zeros((), dtype=jnp.int32),
        "ops": jnp.zeros((), dtype=jnp.int32),
    }


def _tick(state: dict) -> dict:
    state = dict(state)
    state["clock"] = state["clock"] + 1
    state["ops"] = state["ops"] + 1
    return state


def _alloc_slots(state: dict, n: int):
    """Pick ``n`` slots: invalid rows first, then LRU-evict valid rows.

    Returns slots[n]. One top_k does both jobs — the free-list and the
    paper's capacity-pressure expiry. (The eviction count is computed by
    the caller, which knows the row mask.)"""
    valid = state["valid"]
    accessed = state["cols"]["_accessed"]
    # invalid rows get key -1 (< any clock stamp, clocks start at 0)
    key = jnp.where(valid, accessed, -1)
    _, slots = jax.lax.top_k(-key, n)  # n smallest keys
    return slots


def insert(
    schema: TableSchema,
    state: dict,
    values: Mapping[str, jax.Array],
    payloads: Mapping[str, jax.Array] | None = None,
    row_mask: jax.Array | None = None,
    ttl: jax.Array | int = 0,
):
    """Insert a batch of rows. ``values[col]`` has shape [n]; all columns
    not supplied default to 0. ``row_mask`` ([n] bool) lets a fixed-width
    executor insert fewer than n rows (padding support).

    Returns (state, slots[n], evicted_count)."""
    payloads = payloads or {}
    n = None
    for v in values.values():
        n = np.shape(v)[0]
        break
    for v in payloads.values():
        n = np.shape(v)[0] if n is None else n
        break
    if n is None:
        raise ValueError("insert needs at least one column or payload")
    slots = _alloc_slots(state, n)
    if row_mask is None:
        row_mask = jnp.ones((n,), dtype=bool)
    # Rows whose mask is off write to a scratch slot? No — we redirect them
    # onto themselves by scattering with mode='drop' on an out-of-range index.
    cap = schema.capacity
    tgt = jnp.where(row_mask, slots, cap)  # cap is out-of-range -> dropped

    cols = dict(state["cols"])
    for c in schema.columns:
        vals = values.get(c.name)
        if vals is None:
            vals = jnp.zeros((n,), dtype=c.dtype)
        else:
            vals = jnp.asarray(vals).astype(c.dtype)
        cols[c.name] = cols[c.name].at[tgt].set(vals, mode="drop")
    now = state["clock"].astype(jnp.int32)
    now_b = jnp.broadcast_to(now, (n,))
    cols["_created"] = cols["_created"].at[tgt].set(now_b, mode="drop")
    cols["_accessed"] = cols["_accessed"].at[tgt].set(now_b, mode="drop")
    ttl_b = jnp.broadcast_to(jnp.asarray(ttl, dtype=jnp.int32), (n,))
    cols["_ttl"] = cols["_ttl"].at[tgt].set(ttl_b, mode="drop")

    pls = dict(state["payloads"])
    for p in schema.payloads:
        if p.name in payloads:
            pv = jnp.asarray(payloads[p.name]).astype(p.dtype)
            pls[p.name] = pls[p.name].at[tgt].set(pv, mode="drop")

    valid = state["valid"].at[tgt].set(True, mode="drop")
    new_state = dict(state, cols=cols, payloads=pls, valid=valid)
    new_state = _tick(new_state)
    # only count evictions of rows we actually overwrote
    evicted = jnp.sum((state["valid"][slots] & row_mask).astype(jnp.int32))
    return new_state, slots, evicted


def _match_mask(schema: TableSchema, state: dict, where: P.Node | None, params):
    mask = P.eval_predicate(where, state["cols"], params, schema.capacity)
    return mask & state["valid"]


@functools.lru_cache(maxsize=4096)
def _fused_plan(schema: TableSchema, where) -> P.FusedScan | None:
    """Classify a WHERE clause against this schema's int32 columns (the
    relscan-fusable set: INT/TEXT user columns + the reserved clocks)."""
    int_cols = frozenset(
        c.name for c in schema.columns
        if np.dtype(SQL_TYPES[c.sql_type.upper()]) == np.int32
    ) | frozenset(RESERVED_COLUMNS)
    return P.classify_fusable(where, int_cols)


def _fused_scan(schema, state, plan: P.FusedScan, params, *, limit,
                want_ids=True, mode=None):
    """Dispatch a classified predicate to the fused relscan path. Returns
    (ids, present, mask, count) or None if a runtime param has a non-int
    dtype (decided at trace time — dtypes are static under jit)."""
    vals = [t.resolve(params) for t in plan.terms]
    if not all(
        jnp.issubdtype(jnp.result_type(v), jnp.integer) for v in vals
    ):
        return None
    cols_t = tuple(state["cols"][c] for c in plan.columns)
    return OPS.predicate_scan(
        cols_t, state["valid"], jnp.asarray(vals, jnp.int32),
        ops=plan.ops, limit=limit, want_ids=want_ids, mode=mode)


def _compact(mask: jax.Array, limit: int, capacity: int):
    """Indices of the first ``limit`` set bits (row order), padded.

    Pure-jnp path (argmax / one-hot contraction — see kernels/relscan
    ``compact``); the Pallas ``relscan`` kernel implements the same
    contract in-kernel for on-TPU pools."""
    from repro.kernels.relscan import compact
    return compact(mask, limit=min(limit, capacity))


def select(
    schema: TableSchema,
    state: dict,
    where: P.Node | None,
    params: Sequence[Any] = (),
    *,
    columns: Sequence[str] | None = None,
    order_by: str | None = None,
    descending: bool = False,
    limit: int | None = None,
    with_payloads: Sequence[str] = (),
    touch: bool = True,
    active: jax.Array | None = None,
    fused_mode: str | None = None,
):
    """SELECT. Returns (state, result dict).

    result = {"count": scalar, "rows": {col: [limit]}, "present": bool[limit],
              "payloads": {name: [limit, *shape]}}

    ``active`` (scalar bool) no-ops the whole statement — count 0, nothing
    present, no touch — so the daemon's micro-batch executor can pad its
    scan to a fixed bucket without side effects.
    """
    limit = schema.max_select if limit is None else min(limit, schema.max_select)
    fused = None
    if order_by is None:
        plan = _fused_plan(schema, where)
        if plan is not None:
            fused = _fused_scan(schema, state, plan, params, limit=limit,
                                mode=fused_mode)
    if fused is not None:
        idx, present, mask, count = fused
    elif order_by is not None:
        mask = _match_mask(schema, state, where, params)
        count = jnp.sum(mask.astype(jnp.int32))
        key = state["cols"][order_by]
        if jnp.issubdtype(key.dtype, jnp.integer):
            # monotone integer key: ~k = -k-1 flips the order without the
            # float32 cast (which collapses int32 values above 2^24) and
            # without the -k overflow at iinfo.min
            key = key if descending else ~key
            key = jnp.where(mask, key, jnp.iinfo(key.dtype).min)
        else:
            key = key if descending else -key
            key = jnp.where(mask, key, -jnp.inf)
        _, idx = jax.lax.top_k(key, limit)
        present = mask[idx]
        idx = idx.astype(jnp.int32)
    else:
        mask = _match_mask(schema, state, where, params)
        count = jnp.sum(mask.astype(jnp.int32))
        idx, present = _compact(mask, limit, schema.capacity)
    if active is not None:
        count = jnp.where(active, count, 0)
        present = present & active
        mask = mask & active  # gates the touch below
    columns = tuple(columns) if columns is not None else schema.column_names
    rows = {c: state["cols"][c][idx] for c in columns}
    pls = {p: state["payloads"][p][idx] for p in with_payloads}
    if touch:
        cols = dict(state["cols"])
        now = state["clock"].astype(jnp.int32)
        touched = jnp.where(mask, now, cols["_accessed"])
        cols["_accessed"] = touched
        state = dict(state, cols=cols)
    state = _tick(state)
    return state, {
        "count": count,
        "rows": rows,
        "present": present,
        "row_ids": idx,
        "payloads": pls,
    }


def update(
    schema: TableSchema,
    state: dict,
    where: P.Node | None,
    set_exprs: Mapping[str, P.Node],
    params: Sequence[Any] = (),
    *,
    extra_mask: jax.Array | None = None,
):
    """UPDATE t SET col = expr ... WHERE pred. Returns (state, n_updated).
    ``extra_mask`` gates the match (micro-batch padding support)."""
    plan = _fused_plan(schema, where)
    fused = None
    if plan is not None:
        fused = _fused_scan(schema, state, plan, params, limit=1,
                            want_ids=False)
    if fused is not None:
        mask = fused[2]
    else:
        mask = _match_mask(schema, state, where, params)
    if extra_mask is not None:
        mask = mask & extra_mask
    cols = dict(state["cols"])
    for name, expr in set_exprs.items():
        tgt = "_ttl" if name.upper() == "TTL" else name
        spec_dtype = cols[tgt].dtype
        newv = P.eval_expr(expr, state["cols"], params)
        newv = jnp.broadcast_to(jnp.asarray(newv, dtype=spec_dtype), (schema.capacity,))
        cols[tgt] = jnp.where(mask, newv, cols[tgt])
    n = jnp.sum(mask.astype(jnp.int32))
    state = dict(state, cols=cols)
    state = _tick(state)
    return state, n


def _delete_mask(schema, state, where, params, *, want_ids, limit):
    plan = _fused_plan(schema, where)
    fused = None
    if plan is not None:
        fused = _fused_scan(schema, state, plan, params,
                            limit=limit, want_ids=want_ids)
    if fused is not None:
        return fused
    mask = _match_mask(schema, state, where, params)
    n = jnp.sum(mask.astype(jnp.int32))
    if not want_ids:
        return None, None, mask, n
    ids, present = _compact(mask, limit, schema.capacity)
    return ids, present, mask, n


def delete(
    schema: TableSchema,
    state: dict,
    where: P.Node | None,
    params: Sequence[Any] = (),
    *,
    extra_mask: jax.Array | None = None,
):
    """DELETE FROM t WHERE pred — flips validity bits only; payload bytes
    never move (the 0.2 ms-vs-1000 ms effect from the paper's Table 2).
    ``extra_mask`` (scalar or [cap] bool) further gates the match — the
    daemon's micro-batch executor uses it to no-op padded statements."""
    _, _, mask, n = _delete_mask(schema, state, where, params,
                                 want_ids=False, limit=1)
    if extra_mask is not None:
        mask = mask & extra_mask
        n = jnp.sum(mask.astype(jnp.int32))
    state = dict(state, valid=state["valid"] & ~mask)
    state = _tick(state)
    return state, n


def delete_many_eq(
    schema: TableSchema,
    state: dict,
    column: str,
    vals: jax.Array,
    active: jax.Array,
):
    """One-pass multi-value equality DELETE: flip every valid row whose
    ``column`` equals ANY active entry of ``vals`` — W statements, ONE scan
    over the table (sort the W values, binary-search each row into them).
    The count equals the sequential per-statement total because deletes
    commute. INT32_MAX is reserved as the padding sentinel. The logical
    clock advances by the number of ACTIVE statements (padding is free),
    matching the sequential path's TTL semantics.

    Returns (state, n_deleted)."""
    w = vals.shape[0]
    sentinel = jnp.iinfo(jnp.int32).max
    sv = jnp.sort(jnp.where(active, vals.astype(jnp.int32), sentinel))
    n_act = jnp.sum(active.astype(jnp.int32))
    col = state["cols"][column]
    pos = jnp.clip(jnp.searchsorted(sv, col), 0, w - 1)
    hit = state["valid"] & (sv[pos] == col) & (pos < n_act)
    n = jnp.sum(hit.astype(jnp.int32))
    state = dict(state, valid=state["valid"] & ~hit)
    state["clock"] = state["clock"] + n_act
    state["ops"] = state["ops"] + n_act
    return state, n


def delete_returning(
    schema: TableSchema,
    state: dict,
    where: P.Node | None,
    params: Sequence[Any] = (),
    *,
    limit: int | None = None,
):
    """DELETE that also reports which rows went: returns
    (state, n, row_ids[limit], present[limit]). Row ids feed incremental
    index maintenance (kvpool.page_table_update) — the metadata columns of
    deleted rows stay intact, so callers can still read slot/pos there."""
    limit = schema.max_select if limit is None else limit
    ids, present, mask, n = _delete_mask(schema, state, where, params,
                                         want_ids=True, limit=limit)
    state = dict(state, valid=state["valid"] & ~mask)
    state = _tick(state)
    return state, n, ids, present


_AGGS = {
    "COUNT": lambda v, m: jnp.sum(m.astype(jnp.int32)),
    "SUM": lambda v, m: jnp.sum(jnp.where(m, v, 0)),
    "MIN": lambda v, m: jnp.min(jnp.where(m, v, jnp.inf)).astype(v.dtype)
    if jnp.issubdtype(v.dtype, jnp.floating)
    else jnp.min(jnp.where(m, v, jnp.iinfo(v.dtype).max)),
    "MAX": lambda v, m: jnp.max(jnp.where(m, v, -jnp.inf)).astype(v.dtype)
    if jnp.issubdtype(v.dtype, jnp.floating)
    else jnp.max(jnp.where(m, v, jnp.iinfo(v.dtype).min)),
    "AVG": lambda v, m: jnp.sum(jnp.where(m, v.astype(jnp.float32), 0.0))
    / jnp.maximum(jnp.sum(m.astype(jnp.int32)), 1),
}


def aggregate(
    schema: TableSchema,
    state: dict,
    agg: str,
    column: str | None,
    where: P.Node | None,
    params: Sequence[Any] = (),
):
    """COUNT/SUM/MIN/MAX/AVG over the matching rows. Returns (state, value)."""
    mask = _match_mask(schema, state, where, params)
    agg = agg.upper()
    if agg == "COUNT" or column is None:
        val = _AGGS["COUNT"](None, mask)
    else:
        val = _AGGS[agg](state["cols"][column], mask)
    state = _tick(state)
    return state, val


def expire(schema: TableSchema, state: dict):
    """Automatic expiry — the paper's §4.3 conditions 1 (age) and 2 (rows).

    Condition 3 (op count) is the daemon's trigger for calling this.
    Returns (state, n_expired)."""
    pol = schema.expiry
    valid = state["valid"]
    cols = state["cols"]
    now = state["clock"].astype(jnp.int32)
    expired = jnp.zeros_like(valid)

    # 1. data age: per-row _ttl overrides the table default
    default_ttl = jnp.asarray(pol.ttl, dtype=jnp.int32)
    ttl_eff = jnp.where(cols["_ttl"] > 0, cols["_ttl"], default_ttl)
    aged = (ttl_eff > 0) & ((now - cols["_created"]) > ttl_eff)
    expired = expired | (valid & aged)

    # 2. row-count cap: keep the newest max_rows (stable tie-break by row id).
    # Overflow-safe ordering: rank rows by (created, row_id) via double
    # argsort instead of a keyed multiply (which overflows int32 clocks).
    if pol.max_rows > 0 and pol.max_rows < schema.capacity:
        cap = schema.capacity
        live = valid & ~expired
        order = jnp.lexsort((jnp.arange(cap), cols["_created"]))  # old -> new
        rank = jnp.zeros((cap,), dtype=jnp.int32).at[order].set(
            jnp.arange(cap, dtype=jnp.int32)
        )
        # rank among LIVE rows only: count live rows with strictly lower rank
        live_i = live.astype(jnp.int32)
        # cumulative live count in rank order, mapped back to row order
        live_in_rank = live_i[order]
        cum = jnp.cumsum(live_in_rank) - live_in_rank  # live rows older than me
        older_live = jnp.zeros((cap,), dtype=jnp.int32).at[order].set(cum)
        n_live = jnp.sum(live_i)
        # drop the oldest (n_live - max_rows): live rows whose "younger live
        # count" = n_live - older_live - 1 >= max_rows
        younger = n_live - older_live - 1
        drop = live & (younger >= pol.max_rows)
        expired = expired | drop

    n = jnp.sum(expired.astype(jnp.int32))
    state = dict(state, valid=valid & ~expired)
    state = _tick(state)
    return state, n


def flush(schema: TableSchema, state: dict):
    """Drop every row (memcached's only bulk invalidation mode)."""
    n = jnp.sum(state["valid"].astype(jnp.int32))
    state = dict(state, valid=jnp.zeros_like(state["valid"]))
    state = _tick(state)
    return state, n


def live_count(state: dict) -> jax.Array:
    return jnp.sum(state["valid"].astype(jnp.int32))
