"""RelTable: a fixed-capacity, device-resident relational cache table,
executed as *plans*.

The TPU-native reimagining of SQLcached's SQLite-backed store (DESIGN.md
§2): storage is struct-of-arrays with a validity bitmap; every operation
is a *pure function* ``(state, ...) -> (state, result)`` so the daemon can
jit + donate it and thread it through pjit programs; slot allocation
unifies the free list with LRU eviction (one ``top_k``); a logical clock
stamps ``_created`` / ``_accessed`` and drives the paper's three automatic
expiry conditions (§4.3, :func:`expire`).

Query execution is a two-stage affair since the planner split:

1. ``core/planner.plan_where`` lowers the WHERE AST into a Plan —
   IndexProbe | FusedScan | GenericScan (memoized per schema × AST; the
   prepared-statement planner cache).
2. ``select`` / ``update`` / ``delete`` / ``aggregate`` here are thin
   *plan executors*: they route the plan to the matching device program —
   a hash-bucket probe (kernels/hashidx), the fused Pallas relscan
   (kernels/relscan), or the generic jnp masked scan — and share one
   epilogue (touch, compaction contract, clock tick).

Index-probe execution is O(bucket_cap), independent of table capacity.
Because a bucket can overflow (``stale``), every probing executor embeds
its fallback scan behind a device-side ``lax.cond`` on the index's stale
flag — plan revalidation costs zero host syncs. Index maintenance is
fused into the mutating executors: ``insert`` re-homes each written slot
(clearing the overwritten row's entry via its still-readable old key —
the kvpool page-table trick), ``update`` rebuilds any index whose column
it sets, and DELETE/FLUSH/EXPIRE touch nothing (dead entries are masked
by the validity gather at probe time and reclaimed on slot reuse).

Callers may pass ``plan=`` explicitly to force a route (the parity suite
and the daemon's batched executors do); a forced IndexProbe skips the
staleness cond and trusts the caller.

Row results of SELECT are fixed-size (``schema.max_select``) with an
exact ``count`` — the host slices; payload gathers stay on device for
zero-copy hand-off to compute (e.g. paged attention reading KV blocks).
"""
from __future__ import annotations

from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import planner as PL
from repro.core import predicate as P
from repro.core.schema import RESERVED_COLUMNS, TableSchema
from repro.kernels import hashidx as HX
from repro.kernels import ops as OPS

CLOCK_DTYPE = jnp.int64 if jax.config.read("jax_enable_x64") else jnp.int32
# NOTE: we keep clocks in int32 unless x64 is enabled; the daemon widens by
# running with jax_enable_x64 when available. 2^31 ops is plenty for tests.

# multi-value eq DELETE batches up to this wide use direct per-value
# compares; wider ones sort the values and binary-search each row once
_EQ_DIRECT_MAX = 16

# INSERT batches at least this wide maintain hash indexes by ONE bulk
# sort-based rebuild (kernels/hashidx.build) instead of the sequential
# per-slot re-home fori_loop — the loop's O(batch) serial chain dominates
# large bulk loads, while the rebuild is one O(cap log cap) sort whatever
# the batch width. The rebuild is complete by construction, so it also
# RESETS a stale flag whenever the live rows fit their buckets again.
BULK_INDEX_THRESHOLD = 64


def init_state(schema: TableSchema) -> dict:
    cap = schema.capacity
    cols = {c.name: jnp.zeros((cap,), dtype=c.dtype) for c in schema.columns}
    for r in RESERVED_COLUMNS:
        cols[r] = jnp.zeros((cap,), dtype=jnp.int32)
    payloads = {
        p.name: jnp.zeros((cap,) + p.shape, dtype=p.dtype) for p in schema.payloads
    }
    nb = HX.n_buckets_for(cap)
    indexes = {c: HX.empty_index(nb) for c in schema.indexes}
    return {
        "cols": cols,
        "payloads": payloads,
        "valid": jnp.zeros((cap,), dtype=bool),
        "clock": jnp.zeros((), dtype=jnp.int32),
        "ops": jnp.zeros((), dtype=jnp.int32),
        "indexes": indexes,
    }


def _tick(state: dict) -> dict:
    state = dict(state)
    state["clock"] = state["clock"] + 1
    state["ops"] = state["ops"] + 1
    return state


def _free_slots(state: dict, n: int):
    """The first ``n`` invalid row ids, via ONE cumsum + ``n`` binary
    searches (the k-th free slot is where the running free count reaches
    k). O(capacity) with a tiny constant — more than 10x cheaper than the
    top_k it replaces on large tables. Only exact when the table has at
    least ``n`` free slots (the caller conds on that)."""
    cum = jnp.cumsum((~state["valid"]).astype(jnp.int32))
    return jnp.searchsorted(
        cum, jnp.arange(1, n + 1, dtype=jnp.int32)).astype(jnp.int32)


def _lru_slots(state: dict, n: int):
    """Invalid rows first (key -1 < any clock stamp), then LRU-evict valid
    rows — one top_k does both the free list and the paper's capacity-
    pressure expiry. Ties (all-invalid) break toward lower row ids, so
    this matches ``_free_slots`` whenever that path is applicable."""
    valid = state["valid"]
    accessed = state["cols"]["_accessed"]
    key = jnp.where(valid, accessed, -1)
    _, slots = jax.lax.top_k(-key, n)  # n smallest keys
    return slots


def _alloc_slots(state: dict, n: int, alloc: str | None = None):
    """Pick ``n`` slots: invalid rows first, then LRU-evict valid rows.

    The common case (table not full) takes the cheap free-list path; a
    device-side cond falls back to the LRU top_k under capacity pressure.
    ``alloc`` pins a path statically: executors running under vmap hoist
    the cond OUTSIDE the vmap (a vmapped cond lowers to select and would
    pay for BOTH paths) — "free" asserts the caller checked the free
    count, "lru" always evicts correctly. (The eviction count is computed
    by the caller, which knows the row mask.)"""
    if alloc == "free":
        return _free_slots(state, n)
    if alloc == "lru":
        return _lru_slots(state, n)
    return jax.lax.cond(
        jnp.sum((~state["valid"]).astype(jnp.int32)) >= n,
        lambda _: _free_slots(state, n),
        lambda _: _lru_slots(state, n),
        None)


def insert(
    schema: TableSchema,
    state: dict,
    values: Mapping[str, jax.Array],
    payloads: Mapping[str, jax.Array] | None = None,
    row_mask: jax.Array | None = None,
    ttl: jax.Array | int = 0,
    index_mode: str | None = None,
    alloc: str | None = None,
):
    """Insert a batch of rows. ``values[col]`` has shape [n]; all columns
    not supplied default to 0. ``row_mask`` ([n] bool) lets a fixed-width
    executor insert fewer than n rows (padding support). Hash-index
    maintenance for ``schema.indexes`` is fused in: batches narrower than
    ``BULK_INDEX_THRESHOLD`` re-home all written slots in one batched
    clear + rank-place pass (``HX.insert_update_batched`` — no serial
    per-slot chain); wider batches take ONE bulk sort-based rebuild
    instead. ``index_mode`` pins the bulk build's kernel
    implementation (executors running under vmap pass ``"ref"``);
    ``alloc`` pins the slot-allocator path (see ``_alloc_slots``).

    Returns (state, slots[n], evicted_count)."""
    payloads = payloads or {}
    n = None
    for v in values.values():
        n = np.shape(v)[0]
        break
    for v in payloads.values():
        n = np.shape(v)[0] if n is None else n
        break
    if n is None:
        raise ValueError("insert needs at least one column or payload")
    slots = _alloc_slots(state, n, alloc)
    if row_mask is None:
        row_mask = jnp.ones((n,), dtype=bool)
    # Rows whose mask is off write to a scratch slot? No — we redirect them
    # onto themselves by scattering with mode='drop' on an out-of-range index.
    cap = schema.capacity
    tgt = jnp.where(row_mask, slots, cap)  # cap is out-of-range -> dropped

    cols = dict(state["cols"])
    for c in schema.columns:
        vals = values.get(c.name)
        if vals is None:
            vals = jnp.zeros((n,), dtype=c.dtype)
        else:
            vals = jnp.asarray(vals).astype(c.dtype)
        cols[c.name] = cols[c.name].at[tgt].set(vals, mode="drop")
    now = state["clock"].astype(jnp.int32)
    now_b = jnp.broadcast_to(now, (n,))
    cols["_created"] = cols["_created"].at[tgt].set(now_b, mode="drop")
    cols["_accessed"] = cols["_accessed"].at[tgt].set(now_b, mode="drop")
    ttl_b = jnp.broadcast_to(jnp.asarray(ttl, dtype=jnp.int32), (n,))
    cols["_ttl"] = cols["_ttl"].at[tgt].set(ttl_b, mode="drop")

    pls = dict(state["payloads"])
    for p in schema.payloads:
        if p.name in payloads:
            pv = jnp.asarray(payloads[p.name]).astype(p.dtype)
            pls[p.name] = pls[p.name].at[tgt].set(pv, mode="drop")

    valid = state["valid"].at[tgt].set(True, mode="drop")
    indexes = state.get("indexes", {})
    if schema.indexes and indexes:
        row_mask_b = jnp.asarray(row_mask, dtype=bool)
        upd = {}
        if n >= BULK_INDEX_THRESHOLD:
            # bulk-load fast path: one sort-based rebuild from the
            # post-insert columns replaces the O(n) serial re-home chain
            nb = HX.n_buckets_for(cap)
            for ixc in schema.indexes:
                rid, key, overflow = OPS.hash_build(
                    cols[ixc], valid, n_buckets=nb, mode=index_mode)
                upd[ixc] = {"rid": rid, "key": key, "stale": overflow}
        else:
            for ixc in schema.indexes:
                # old keys come from the PRE-insert column (they name the
                # bucket holding the overwritten slot's entry)
                upd[ixc] = HX.insert_update_batched(
                    indexes[ixc], slots, state["cols"][ixc][slots],
                    cols[ixc][slots], row_mask_b, valid)
        indexes = dict(indexes, **upd)
    new_state = dict(state, cols=cols, payloads=pls, valid=valid,
                     indexes=indexes)
    new_state = _tick(new_state)
    # only count evictions of rows we actually overwrote
    evicted = jnp.sum((state["valid"][slots] & row_mask).astype(jnp.int32))
    return new_state, slots, evicted


def _match_mask(schema: TableSchema, state: dict, where: P.Node | None, params):
    mask = P.eval_predicate(where, state["cols"], params, schema.capacity)
    return mask & state["valid"]


def plan_for(schema: TableSchema, where, ranked: bool = False) -> PL.Plan:
    """The memoized plan for one WHERE against this schema (``ranked``
    marks ORDER BY statements — the planner sends those to the scan)."""
    return PL.plan_where(schema, where, ranked)


def _fused_plan(schema: TableSchema, where) -> P.FusedScan | None:
    """Legacy shim: the <=4-term fused-conjunction view of the plan (what
    ``classify_fusable`` used to return) — still used by the batched-DML
    eq-shape detection and the parity suites."""
    return PL.as_fused(PL.plan_where(schema, where))


def _fused_scan(schema, state, plan: P.FusedScan, params, *, limit,
                want_ids=True, mode=None):
    """Dispatch a classified predicate to the fused relscan path. Returns
    (ids, present, mask, count) or None if a runtime param has a non-int
    dtype (decided at trace time — dtypes are static under jit)."""
    vals = [t.resolve(params) for t in plan.terms]
    if not all(
        jnp.issubdtype(jnp.result_type(v), jnp.integer) for v in vals
    ):
        return None
    cols_t = tuple(state["cols"][c] for c in plan.columns)
    return OPS.predicate_scan(
        cols_t, state["valid"], jnp.asarray(vals, jnp.int32),
        ops=plan.ops, limit=limit, want_ids=want_ids, mode=mode)


def _compact(mask: jax.Array, limit: int, capacity: int):
    """Indices of the first ``limit`` set bits (row order), padded.

    Pure-jnp path (argmax / one-hot contraction — see kernels/relscan
    ``compact``); the Pallas ``relscan`` kernel implements the same
    contract in-kernel for on-TPU pools."""
    from repro.kernels.relscan import compact
    return compact(mask, limit=min(limit, capacity))


# ------------------------------------------------------ index-probe pieces

def index_fresh(state: dict, column: str) -> jax.Array:
    """Scalar bool: the hash index on ``column`` has never overflowed (a
    probe is complete). Executors cond their scan fallback on this."""
    return state["indexes"][column]["stale"] == 0


def _int_values(terms, params) -> bool:
    """Trace-time check: every term's runtime value has an integer dtype
    (a float bound to an int column must keep exact-compare semantics and
    demotes the plan to its scan fallback)."""
    return all(
        jnp.issubdtype(jnp.result_type(t.resolve(params)), jnp.integer)
        for t in terms
    )


def _probe_candidates(schema, state, plan: PL.IndexProbe, params, *,
                      mode=None, extra_mask=None):
    """One hash-bucket probe + candidate verification.

    Returns (safe [bucket_cap] clipped row ids, ok [bucket_cap] match
    bits): ``ok`` ANDs the bucket hit (lane occupied, stored key equal),
    the live key column (belt and braces for the entry invariant), the
    validity bitmap and every residual term — all gathers over one
    bucket, O(bucket_cap) regardless of capacity."""
    cap = schema.capacity
    idx = state["indexes"][plan.column]
    qv = jnp.asarray(plan.key.resolve(params), jnp.int32)
    cand, hit = OPS.hash_probe(idx["rid"], idx["key"], qv[None], mode=mode)
    cand, hit = cand[0], hit[0]
    safe = jnp.clip(cand, 0, cap - 1)
    ok = hit & state["valid"][safe] & (state["cols"][plan.column][safe] == qv)
    for t in plan.residual:
        tv = jnp.asarray(t.resolve(params), jnp.int32)
        ok = ok & P._CMP[t.op](state["cols"][t.col][safe], tv)
    if extra_mask is not None:
        ok = ok & jnp.broadcast_to(extra_mask, (cap,))[safe]
    return safe, ok


def _probe_ids(safe, ok, limit: int, capacity: int):
    """Candidate matches -> the scan compaction contract: first ``limit``
    matching row ids in ROW ORDER (0-padded) + presence + count. A fresh
    probe has count <= bucket_cap by construction (one key, one bucket)."""
    count = jnp.sum(ok.astype(jnp.int32))
    ordered = jnp.sort(jnp.where(ok, safe, capacity))
    if limit <= ordered.shape[0]:
        ids = ordered[:limit]
    else:
        ids = jnp.concatenate([
            ordered,
            jnp.full((limit - ordered.shape[0],), capacity, jnp.int32)])
    present = jnp.arange(limit, dtype=jnp.int32) < count
    return jnp.where(present, ids, 0).astype(jnp.int32), present, count


def _route(schema, where, params, plan):
    """Resolve the executor's route: caller-forced plan wins verbatim;
    otherwise the planner's choice, demoted to its fallback when a probe
    term is bound to a non-integer runtime value (trace-time)."""
    if plan is not None:
        return plan, True
    route = plan_for(schema, where)
    if isinstance(route, PL.IndexProbe) and not _int_values(
            (route.key,) + route.residual, params):
        route = route.fallback
    return route, False


def build_index(schema: TableSchema, state: dict, column: str | None = None,
                *, mode=None) -> dict:
    """(Re)build the hash index(es) from the current column/validity state
    — the bulk path behind CREATE-with-data, UPDATEs that rewrite an
    indexed column, and explicit recovery from a stale (overflowed)
    index. Pure function of the state; jit/fuse freely."""
    cols = [column] if column is not None else list(schema.indexes)
    indexes = dict(state["indexes"])
    nb = HX.n_buckets_for(schema.capacity)
    for c in cols:
        rid, key, overflow = OPS.hash_build(
            state["cols"][c], state["valid"], n_buckets=nb, mode=mode)
        indexes[c] = {"rid": rid, "key": key, "stale": overflow}
    return dict(state, indexes=indexes)


def select(
    schema: TableSchema,
    state: dict,
    where: P.Node | None,
    params: Sequence[Any] = (),
    *,
    columns: Sequence[str] | None = None,
    order_by: str | None = None,
    descending: bool = False,
    limit: int | None = None,
    with_payloads: Sequence[str] = (),
    touch: bool = True,
    active: jax.Array | None = None,
    fused_mode: str | None = None,
    probe_mode: str | None = None,
    plan: PL.Plan | None = None,
):
    """SELECT, executed by plan. Returns (state, result dict).

    result = {"count": scalar, "rows": {col: [limit]}, "present": bool[limit],
              "payloads": {name: [limit, *shape]}}

    ``active`` (scalar bool) no-ops the whole statement — count 0, nothing
    present, no touch — so the daemon's micro-batch executor can pad its
    scan to a fixed bucket without side effects. ``plan`` forces a route
    (see module docstring); ``fused_mode``/``probe_mode`` pin the kernel
    implementation (the vmapped batch executor uses ``ref``).

    Every route returns through one epilogue: (new ``_accessed`` column,
    ids, present, count) — which is also what lets the index-probe route
    and its staleness-fallback scan share a ``lax.cond``.
    """
    limit = schema.max_select if limit is None else min(limit, schema.max_select)
    cap = schema.capacity
    now = state["clock"].astype(jnp.int32)
    accessed = state["cols"]["_accessed"]

    def finish_mask(mask, idx, present, count):
        if active is not None:
            count = jnp.where(active, count, 0)
            present = present & active
            mask = mask & active  # gates the touch below
        acc = jnp.where(mask, now, accessed) if touch else accessed
        return acc, idx.astype(jnp.int32), present, count

    def scan_route(r):
        fused = None
        if isinstance(r, PL.FusedScan):
            fused = _fused_scan(schema, state, r.scan, params, limit=limit,
                                mode=fused_mode)
        if fused is not None:
            idx, present, mask, count = fused
        else:
            mask = _match_mask(schema, state, where, params)
            count = jnp.sum(mask.astype(jnp.int32))
            idx, present = _compact(mask, limit, cap)
        return finish_mask(mask, idx, present, count)

    def probe_route(r):
        safe, ok = _probe_candidates(schema, state, r, params,
                                     mode=probe_mode)
        if active is not None:
            ok = ok & active
        ids, present, count = _probe_ids(safe, ok, limit, cap)
        acc = (accessed.at[jnp.where(ok, safe, cap)].set(now, mode="drop")
               if touch else accessed)
        return acc, ids, present, count

    if order_by is not None:
        # ranked reads stay on the scan path: top_k needs the full mask
        mask = _match_mask(schema, state, where, params)
        count = jnp.sum(mask.astype(jnp.int32))
        key = state["cols"][order_by]
        if jnp.issubdtype(key.dtype, jnp.integer):
            # monotone integer key: ~k = -k-1 flips the order without the
            # float32 cast (which collapses int32 values above 2^24) and
            # without the -k overflow at iinfo.min
            key = key if descending else ~key
            key = jnp.where(mask, key, jnp.iinfo(key.dtype).min)
        else:
            key = key if descending else -key
            key = jnp.where(mask, key, -jnp.inf)
        _, idx = jax.lax.top_k(key, limit)
        present = mask[idx]
        acc, idx, present, count = finish_mask(mask, idx, present, count)
    else:
        route, forced = _route(schema, where, params, plan)
        if isinstance(route, PL.IndexProbe):
            if forced:
                acc, idx, present, count = probe_route(route)
            else:
                acc, idx, present, count = jax.lax.cond(
                    index_fresh(state, route.column),
                    lambda _: probe_route(route),
                    lambda _: scan_route(route.fallback),
                    None)
        else:
            acc, idx, present, count = scan_route(route)

    columns = tuple(columns) if columns is not None else schema.column_names
    rows = {c: state["cols"][c][idx] for c in columns}
    pls = {p: state["payloads"][p][idx] for p in with_payloads}
    if touch:
        state = dict(state, cols=dict(state["cols"], _accessed=acc))
    state = _tick(state)
    return state, {
        "count": count,
        "rows": rows,
        "present": present,
        "row_ids": idx,
        "payloads": pls,
    }


def update(
    schema: TableSchema,
    state: dict,
    where: P.Node | None,
    set_exprs: Mapping[str, P.Node],
    params: Sequence[Any] = (),
    *,
    extra_mask: jax.Array | None = None,
    plan: PL.Plan | None = None,
    probe_mode: str | None = None,
    maintain_indexes: bool = True,
):
    """UPDATE t SET col = expr ... WHERE pred, executed by plan. Returns
    (state, n_updated). ``extra_mask`` gates the match (micro-batch
    padding support). The probe route evaluates SET expressions in
    candidate space (per-bucket gathers + scatters, never a full-column
    where). An UPDATE that writes an indexed column rebuilds that index
    in the same dispatch (``maintain_indexes=False`` lets a batched
    executor defer ONE rebuild to after its scan)."""
    cap = schema.capacity
    set_items = [("_ttl" if name.upper() == "TTL" else name, expr)
                 for name, expr in set_exprs.items()]

    def scan_route(r):
        fused = None
        if isinstance(r, PL.FusedScan):
            fused = _fused_scan(schema, state, r.scan, params, limit=1,
                                want_ids=False)
        mask = (fused[2] if fused is not None
                else _match_mask(schema, state, where, params))
        if extra_mask is not None:
            mask = mask & extra_mask
        cols = dict(state["cols"])
        for tgt, expr in set_items:
            spec_dtype = cols[tgt].dtype
            newv = P.eval_expr(expr, state["cols"], params)
            newv = jnp.broadcast_to(jnp.asarray(newv, dtype=spec_dtype),
                                    (cap,))
            cols[tgt] = jnp.where(mask, newv, cols[tgt])
        return cols, jnp.sum(mask.astype(jnp.int32))

    def probe_route(r):
        safe, ok = _probe_candidates(schema, state, r, params,
                                     mode=probe_mode,
                                     extra_mask=extra_mask)
        gathered = {c: v[safe] for c, v in state["cols"].items()}
        tgt_rows = jnp.where(ok, safe, cap)
        cols = dict(state["cols"])
        for tgt, expr in set_items:
            spec_dtype = cols[tgt].dtype
            newv = P.eval_expr(expr, gathered, params)
            newv = jnp.broadcast_to(jnp.asarray(newv, dtype=spec_dtype),
                                    (safe.shape[0],))
            cols[tgt] = cols[tgt].at[tgt_rows].set(newv, mode="drop")
        return cols, jnp.sum(ok.astype(jnp.int32))

    route, forced = _route(schema, where, params, plan)
    if isinstance(route, PL.IndexProbe):
        if forced:
            cols, n = probe_route(route)
        else:
            cols, n = jax.lax.cond(
                index_fresh(state, route.column),
                lambda _: probe_route(route),
                lambda _: scan_route(route.fallback),
                None)
    else:
        cols, n = scan_route(route)
    state = dict(state, cols=cols)
    if maintain_indexes and schema.indexes:
        written = {tgt for tgt, _ in set_items}
        for ixc in schema.indexes:
            if ixc in written:
                state = build_index(schema, state, ixc, mode=probe_mode)
    state = _tick(state)
    return state, n


def _delete_core(schema, state, where, params, *, want_ids, limit,
                 extra_mask=None, plan=None, probe_mode=None):
    """Shared DELETE executor: returns (valid', n, ids, present) — ids and
    present are None when ``want_ids`` is False. Probe route flips only
    the candidate rows' validity bits (O(bucket_cap) scatter)."""
    cap = schema.capacity
    no_ids = (jnp.zeros((limit,), jnp.int32),
              jnp.zeros((limit,), dtype=bool))

    def scan_route(r):
        # ids must reflect the FINAL (extra_mask-gated) match, identically
        # to probe_route, so the in-kernel compaction serves them only
        # when no extra_mask applies afterwards
        kernel_ids = want_ids and extra_mask is None
        fused = None
        if isinstance(r, PL.FusedScan):
            fused = _fused_scan(schema, state, r.scan, params, limit=limit,
                                want_ids=kernel_ids)
        if fused is not None:
            ids, present, mask, _ = fused
        else:
            mask = _match_mask(schema, state, where, params)
            ids = present = None
        if extra_mask is not None:
            mask = mask & extra_mask
        n = jnp.sum(mask.astype(jnp.int32))
        if want_ids and ids is None:
            ids, present = _compact(mask, limit, cap)
        if not want_ids:
            ids, present = no_ids
        return state["valid"] & ~mask, n, ids, present

    def probe_route(r):
        safe, ok = _probe_candidates(schema, state, r, params,
                                     mode=probe_mode,
                                     extra_mask=extra_mask)
        n = jnp.sum(ok.astype(jnp.int32))
        valid = state["valid"].at[jnp.where(ok, safe, cap)].set(
            False, mode="drop")
        ids, present = (_probe_ids(safe, ok, limit, cap)[:2] if want_ids
                        else no_ids)
        return valid, n, ids, present

    route, forced = _route(schema, where, params, plan)
    if isinstance(route, PL.IndexProbe):
        if forced:
            return probe_route(route)
        return jax.lax.cond(
            index_fresh(state, route.column),
            lambda _: probe_route(route),
            lambda _: scan_route(route.fallback),
            None)
    return scan_route(route)


def delete(
    schema: TableSchema,
    state: dict,
    where: P.Node | None,
    params: Sequence[Any] = (),
    *,
    extra_mask: jax.Array | None = None,
    plan: PL.Plan | None = None,
    probe_mode: str | None = None,
):
    """DELETE FROM t WHERE pred — flips validity bits only; payload bytes
    never move (the 0.2 ms-vs-1000 ms effect from the paper's Table 2).
    ``extra_mask`` (scalar or [cap] bool) further gates the match — the
    daemon's micro-batch executor uses it to no-op padded statements.
    Hash indexes need no maintenance here: dead entries are masked by the
    validity gather at probe time."""
    valid, n, _, _ = _delete_core(schema, state, where, params,
                                  want_ids=False, limit=1,
                                  extra_mask=extra_mask, plan=plan,
                                  probe_mode=probe_mode)
    state = dict(state, valid=valid)
    state = _tick(state)
    return state, n


def delete_many_eq(
    schema: TableSchema,
    state: dict,
    column: str,
    vals: jax.Array,
    active: jax.Array,
    *,
    per_statement: bool = False,
):
    """One-pass multi-value equality DELETE: flip every valid row whose
    ``column`` equals ANY active entry of ``vals`` — W statements, ONE scan
    over the table (sort the W values, binary-search each row into them).
    The count equals the sequential per-statement total because deletes
    commute. INT32_MAX is reserved as the padding sentinel. The logical
    clock advances by the number of ACTIVE statements (padding is free),
    matching the sequential path's TTL semantics.

    ``per_statement=True`` additionally attributes each deleted row to
    ONE statement under sequential semantics — the EARLIEST statement
    carrying that row's value (later duplicates find it already gone).
    The stable sort keeps equal values in admission order, so the first
    lane of each equal-value run is that earliest statement; every row
    scatter-adds its count there. Still one pass — this is what lets the
    wire scheduler keep the fast path while answering every client with
    its own COUNT.

    Returns (state, n_deleted) or (state, n_deleted, counts[W])."""
    w = vals.shape[0]
    sentinel = jnp.iinfo(jnp.int32).max
    keyed = jnp.where(active, vals.astype(jnp.int32), sentinel)
    n_act = jnp.sum(active.astype(jnp.int32))
    col = state["cols"][column]
    valid = state["valid"]
    ns = None
    act = jnp.asarray(active, dtype=bool)
    if per_statement and w <= _EQ_DIRECT_MAX:
        # narrow batches: claim rows statement by statement (a short
        # unrolled chain of compares) — the wide path's O(capacity)
        # attribution scatter costs more than the whole delete here.
        # Inactive lanes must be gated explicitly: their sentinel key
        # would otherwise match genuine INT32_MAX rows.
        remaining = valid
        parts = []
        for i in range(w):
            m = remaining & (col == keyed[i]) & act[i]
            parts.append(jnp.sum(m.astype(jnp.int32)))
            remaining = remaining & ~m
        hit = valid & ~remaining
        ns = jnp.stack(parts)
    elif w <= _EQ_DIRECT_MAX:
        # small batches: W direct compares beat the sort+searchsorted,
        # whose fixed per-row binary-search cost only amortizes wide
        # (inactive lanes gated as above)
        hit = valid & jnp.any(
            (col[None, :] == keyed[:, None]) & act[:, None], axis=0)
    else:
        order = jnp.argsort(keyed, stable=True).astype(jnp.int32)
        sv = keyed[order]
        pos = jnp.clip(jnp.searchsorted(sv, col), 0, w - 1)
        hit = valid & (sv[pos] == col) & (pos < n_act)
        if per_statement:
            # searchsorted('left') lands every row on the FIRST lane of
            # its value's run = the earliest statement with that value
            ns = jnp.zeros((w,), jnp.int32).at[
                jnp.where(hit, order[pos], w)].add(
                    hit.astype(jnp.int32), mode="drop")
    n = jnp.sum(hit.astype(jnp.int32))
    state = dict(state, valid=valid & ~hit)
    state["clock"] = state["clock"] + n_act
    state["ops"] = state["ops"] + n_act
    if not per_statement:
        return state, n
    return state, n, ns


def delete_returning(
    schema: TableSchema,
    state: dict,
    where: P.Node | None,
    params: Sequence[Any] = (),
    *,
    limit: int | None = None,
    plan: PL.Plan | None = None,
    probe_mode: str | None = None,
):
    """DELETE that also reports which rows went: returns
    (state, n, row_ids[limit], present[limit]). Row ids feed incremental
    index maintenance (kvpool.page_table_update) — the metadata columns of
    deleted rows stay intact, so callers can still read slot/pos there."""
    limit = schema.max_select if limit is None else limit
    valid, n, ids, present = _delete_core(schema, state, where, params,
                                          want_ids=True, limit=limit,
                                          plan=plan, probe_mode=probe_mode)
    state = dict(state, valid=valid)
    state = _tick(state)
    return state, n, ids, present


_AGGS = {
    "COUNT": lambda v, m: jnp.sum(m.astype(jnp.int32)),
    "SUM": lambda v, m: jnp.sum(jnp.where(m, v, 0)),
    "MIN": lambda v, m: jnp.min(jnp.where(m, v, jnp.inf)).astype(v.dtype)
    if jnp.issubdtype(v.dtype, jnp.floating)
    else jnp.min(jnp.where(m, v, jnp.iinfo(v.dtype).max)),
    "MAX": lambda v, m: jnp.max(jnp.where(m, v, -jnp.inf)).astype(v.dtype)
    if jnp.issubdtype(v.dtype, jnp.floating)
    else jnp.max(jnp.where(m, v, jnp.iinfo(v.dtype).min)),
    "AVG": lambda v, m: jnp.sum(jnp.where(m, v.astype(jnp.float32), 0.0))
    / jnp.maximum(jnp.sum(m.astype(jnp.int32)), 1),
}


def aggregate(
    schema: TableSchema,
    state: dict,
    agg: str,
    column: str | None,
    where: P.Node | None,
    params: Sequence[Any] = (),
    *,
    plan: PL.Plan | None = None,
    fused_mode: str | None = None,
    probe_mode: str | None = None,
):
    """COUNT/SUM/MIN/MAX/AVG over the matching rows, executed by plan
    (an indexed eq WHERE aggregates over one bucket's candidates instead
    of a full column). Returns (state, value)."""
    agg = agg.upper()

    def reduce(vals, mask):
        if agg == "COUNT" or column is None:
            return _AGGS["COUNT"](None, mask)
        return _AGGS[agg](vals, mask)

    def scan_route(r):
        fused = None
        if isinstance(r, PL.FusedScan):
            fused = _fused_scan(schema, state, r.scan, params, limit=1,
                                want_ids=False, mode=fused_mode)
        mask = (fused[2] if fused is not None
                else _match_mask(schema, state, where, params))
        return reduce(state["cols"][column] if column is not None else None,
                      mask)

    def probe_route(r):
        safe, ok = _probe_candidates(schema, state, r, params,
                                     mode=probe_mode)
        return reduce(state["cols"][column][safe]
                      if column is not None else None, ok)

    route, forced = _route(schema, where, params, plan)
    if isinstance(route, PL.IndexProbe):
        if forced:
            val = probe_route(route)
        else:
            val = jax.lax.cond(
                index_fresh(state, route.column),
                lambda _: probe_route(route),
                lambda _: scan_route(route.fallback),
                None)
    else:
        val = scan_route(route)
    state = _tick(state)
    return state, val


def expire(schema: TableSchema, state: dict):
    """Automatic expiry — the paper's §4.3 conditions 1 (age) and 2 (rows).

    Condition 3 (op count) is the daemon's trigger for calling this.
    Returns (state, n_expired)."""
    pol = schema.expiry
    valid = state["valid"]
    cols = state["cols"]
    now = state["clock"].astype(jnp.int32)
    expired = jnp.zeros_like(valid)

    # 1. data age: per-row _ttl overrides the table default
    default_ttl = jnp.asarray(pol.ttl, dtype=jnp.int32)
    ttl_eff = jnp.where(cols["_ttl"] > 0, cols["_ttl"], default_ttl)
    aged = (ttl_eff > 0) & ((now - cols["_created"]) > ttl_eff)
    expired = expired | (valid & aged)

    # 2. row-count cap: keep the newest max_rows (stable tie-break by row id).
    # Overflow-safe ordering: rank rows by (created, row_id) via double
    # argsort instead of a keyed multiply (which overflows int32 clocks).
    if pol.max_rows > 0 and pol.max_rows < schema.capacity:
        cap = schema.capacity
        live = valid & ~expired
        order = jnp.lexsort((jnp.arange(cap), cols["_created"]))  # old -> new
        rank = jnp.zeros((cap,), dtype=jnp.int32).at[order].set(
            jnp.arange(cap, dtype=jnp.int32)
        )
        # rank among LIVE rows only: count live rows with strictly lower rank
        live_i = live.astype(jnp.int32)
        # cumulative live count in rank order, mapped back to row order
        live_in_rank = live_i[order]
        cum = jnp.cumsum(live_in_rank) - live_in_rank  # live rows older than me
        older_live = jnp.zeros((cap,), dtype=jnp.int32).at[order].set(cum)
        n_live = jnp.sum(live_i)
        # drop the oldest (n_live - max_rows): live rows whose "younger live
        # count" = n_live - older_live - 1 >= max_rows
        younger = n_live - older_live - 1
        drop = live & (younger >= pol.max_rows)
        expired = expired | drop

    n = jnp.sum(expired.astype(jnp.int32))
    state = dict(state, valid=valid & ~expired)
    state = _tick(state)
    return state, n


def flush(schema: TableSchema, state: dict):
    """Drop every row (memcached's only bulk invalidation mode). Hash
    indexes reset to empty — an empty table's index is trivially exact,
    so FLUSH also recovers from a stale (overflowed) index."""
    n = jnp.sum(state["valid"].astype(jnp.int32))
    state = dict(state, valid=jnp.zeros_like(state["valid"]))
    if schema.indexes:
        nb = HX.n_buckets_for(schema.capacity)
        state["indexes"] = {c: HX.empty_index(nb) for c in schema.indexes}
    state = _tick(state)
    return state, n


def live_count(state: dict) -> jax.Array:
    return jnp.sum(state["valid"].astype(jnp.int32))


def batch_touch(schema: TableSchema, state: dict, res: dict,
                active: jax.Array) -> dict:
    """Fused epilogue for the daemon's micro-batched SELECT (one vmapped
    read over W parameter rows): touch the RETURNED rows and advance the
    clock by the active statement count (padding must not age TTLs).
    ``core/shards.batch_touch`` is the stacked-state twin — the daemon
    calls whichever engine owns the table."""
    now = state["clock"].astype(jnp.int32)
    tgt = jnp.where(res["present"], res["row_ids"], schema.capacity)
    cols = dict(state["cols"])
    cols["_accessed"] = cols["_accessed"].at[tgt.reshape(-1)].set(
        now, mode="drop")
    nact = jnp.sum(active.astype(jnp.int32))
    return dict(state, cols=cols, clock=state["clock"] + nact,
                ops=state["ops"] + nact)
