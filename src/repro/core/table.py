"""RelTable: a fixed-capacity, device-resident relational cache table.

The TPU-native reimagining of SQLcached's SQLite-backed store (DESIGN.md §2):

- storage is struct-of-arrays with a validity bitmap — no pointers, no
  B-trees; every query is a vectorized masked scan (VPU-friendly, jit-able
  with fixed shapes);
- every operation is a *pure function* ``(state, ...) -> (state, result)``
  so the daemon can jit + donate it and thread it through pjit programs;
- slot allocation unifies the free list with LRU eviction: one ``top_k``
  over ``where(valid, _accessed, -1)`` picks invalid rows first, then the
  least-recently-used valid rows (the paper's "number of records" expiry
  becomes the allocator itself);
- a logical clock stamps ``_created`` / ``_accessed``; the paper's three
  automatic expiry conditions (age / row count / op count, §4.3) are
  implemented in :func:`expire`.

Row results of SELECT are fixed-size (``schema.max_select``) with an exact
``count`` — the host slices; payload gathers stay on device for zero-copy
hand-off to compute (e.g. paged attention reading KV blocks).
"""
from __future__ import annotations

import functools
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import predicate as P
from repro.core.schema import RESERVED_COLUMNS, TableSchema

CLOCK_DTYPE = jnp.int64 if jax.config.read("jax_enable_x64") else jnp.int32
# NOTE: we keep clocks in int32 unless x64 is enabled; the daemon widens by
# running with jax_enable_x64 when available. 2^31 ops is plenty for tests.


def init_state(schema: TableSchema) -> dict:
    cap = schema.capacity
    cols = {c.name: jnp.zeros((cap,), dtype=c.dtype) for c in schema.columns}
    for r in RESERVED_COLUMNS:
        cols[r] = jnp.zeros((cap,), dtype=jnp.int32)
    payloads = {
        p.name: jnp.zeros((cap,) + p.shape, dtype=p.dtype) for p in schema.payloads
    }
    return {
        "cols": cols,
        "payloads": payloads,
        "valid": jnp.zeros((cap,), dtype=bool),
        "clock": jnp.zeros((), dtype=jnp.int32),
        "ops": jnp.zeros((), dtype=jnp.int32),
    }


def _tick(state: dict) -> dict:
    state = dict(state)
    state["clock"] = state["clock"] + 1
    state["ops"] = state["ops"] + 1
    return state


def _alloc_slots(state: dict, n: int):
    """Pick ``n`` slots: invalid rows first, then LRU-evict valid rows.

    Returns (slots[n], evicted_count). One top_k does both jobs — the
    free-list and the paper's capacity-pressure expiry."""
    valid = state["valid"]
    accessed = state["cols"]["_accessed"]
    # invalid rows get key -1 (< any clock stamp, clocks start at 0)
    key = jnp.where(valid, accessed, -1)
    _, slots = jax.lax.top_k(-key, n)  # n smallest keys
    evicted = jnp.sum(valid[slots].astype(jnp.int32))
    return slots, evicted


def insert(
    schema: TableSchema,
    state: dict,
    values: Mapping[str, jax.Array],
    payloads: Mapping[str, jax.Array] | None = None,
    row_mask: jax.Array | None = None,
    ttl: jax.Array | int = 0,
):
    """Insert a batch of rows. ``values[col]`` has shape [n]; all columns
    not supplied default to 0. ``row_mask`` ([n] bool) lets a fixed-width
    executor insert fewer than n rows (padding support).

    Returns (state, slots[n], evicted_count)."""
    payloads = payloads or {}
    n = None
    for v in values.values():
        n = np.shape(v)[0]
        break
    for v in payloads.values():
        n = np.shape(v)[0] if n is None else n
        break
    if n is None:
        raise ValueError("insert needs at least one column or payload")
    slots, evicted = _alloc_slots(state, n)
    if row_mask is None:
        row_mask = jnp.ones((n,), dtype=bool)
    # Rows whose mask is off write to a scratch slot? No — we redirect them
    # onto themselves by scattering with mode='drop' on an out-of-range index.
    cap = schema.capacity
    tgt = jnp.where(row_mask, slots, cap)  # cap is out-of-range -> dropped

    cols = dict(state["cols"])
    for c in schema.columns:
        vals = values.get(c.name)
        if vals is None:
            vals = jnp.zeros((n,), dtype=c.dtype)
        else:
            vals = jnp.asarray(vals).astype(c.dtype)
        cols[c.name] = cols[c.name].at[tgt].set(vals, mode="drop")
    now = state["clock"].astype(jnp.int32)
    now_b = jnp.broadcast_to(now, (n,))
    cols["_created"] = cols["_created"].at[tgt].set(now_b, mode="drop")
    cols["_accessed"] = cols["_accessed"].at[tgt].set(now_b, mode="drop")
    ttl_b = jnp.broadcast_to(jnp.asarray(ttl, dtype=jnp.int32), (n,))
    cols["_ttl"] = cols["_ttl"].at[tgt].set(ttl_b, mode="drop")

    pls = dict(state["payloads"])
    for p in schema.payloads:
        if p.name in payloads:
            pv = jnp.asarray(payloads[p.name]).astype(p.dtype)
            pls[p.name] = pls[p.name].at[tgt].set(pv, mode="drop")

    valid = state["valid"].at[tgt].set(True, mode="drop")
    new_state = dict(state, cols=cols, payloads=pls, valid=valid)
    new_state = _tick(new_state)
    # only count evictions of rows we actually overwrote
    evicted = jnp.sum((state["valid"][slots] & row_mask).astype(jnp.int32))
    return new_state, slots, evicted


def _match_mask(schema: TableSchema, state: dict, where: P.Node | None, params):
    mask = P.eval_predicate(where, state["cols"], params, schema.capacity)
    return mask & state["valid"]


def _compact(mask: jax.Array, limit: int, capacity: int):
    """Indices of the first ``limit`` set bits (row order), padded.

    Pure-jnp path; the Pallas ``relscan`` kernel implements the same
    contract for on-TPU pools (see kernels/relscan.py)."""
    idx = jnp.nonzero(mask, size=limit, fill_value=capacity)[0]
    present = idx < capacity
    return jnp.where(present, idx, 0).astype(jnp.int32), present


def select(
    schema: TableSchema,
    state: dict,
    where: P.Node | None,
    params: Sequence[Any] = (),
    *,
    columns: Sequence[str] | None = None,
    order_by: str | None = None,
    descending: bool = False,
    limit: int | None = None,
    with_payloads: Sequence[str] = (),
    touch: bool = True,
):
    """SELECT. Returns (state, result dict).

    result = {"count": scalar, "rows": {col: [limit]}, "present": bool[limit],
              "payloads": {name: [limit, *shape]}}
    """
    limit = schema.max_select if limit is None else min(limit, schema.max_select)
    mask = _match_mask(schema, state, where, params)
    count = jnp.sum(mask.astype(jnp.int32))
    if order_by is not None:
        key = state["cols"][order_by].astype(jnp.float32)
        key = key if descending else -key
        key = jnp.where(mask, key, -jnp.inf)
        _, idx = jax.lax.top_k(key, limit)
        present = mask[idx]
        idx = idx.astype(jnp.int32)
    else:
        idx, present = _compact(mask, limit, schema.capacity)
    columns = tuple(columns) if columns is not None else schema.column_names
    rows = {c: state["cols"][c][idx] for c in columns}
    pls = {p: state["payloads"][p][idx] for p in with_payloads}
    if touch:
        cols = dict(state["cols"])
        now = state["clock"].astype(jnp.int32)
        touched = jnp.where(mask, now, cols["_accessed"])
        cols["_accessed"] = touched
        state = dict(state, cols=cols)
    state = _tick(state)
    return state, {
        "count": count,
        "rows": rows,
        "present": present,
        "row_ids": idx,
        "payloads": pls,
    }


def update(
    schema: TableSchema,
    state: dict,
    where: P.Node | None,
    set_exprs: Mapping[str, P.Node],
    params: Sequence[Any] = (),
):
    """UPDATE t SET col = expr ... WHERE pred. Returns (state, n_updated)."""
    mask = _match_mask(schema, state, where, params)
    cols = dict(state["cols"])
    for name, expr in set_exprs.items():
        tgt = "_ttl" if name.upper() == "TTL" else name
        spec_dtype = cols[tgt].dtype
        newv = P.eval_expr(expr, state["cols"], params)
        newv = jnp.broadcast_to(jnp.asarray(newv, dtype=spec_dtype), (schema.capacity,))
        cols[tgt] = jnp.where(mask, newv, cols[tgt])
    n = jnp.sum(mask.astype(jnp.int32))
    state = dict(state, cols=cols)
    state = _tick(state)
    return state, n


def delete(
    schema: TableSchema,
    state: dict,
    where: P.Node | None,
    params: Sequence[Any] = (),
):
    """DELETE FROM t WHERE pred — flips validity bits only; payload bytes
    never move (the 0.2 ms-vs-1000 ms effect from the paper's Table 2)."""
    mask = _match_mask(schema, state, where, params)
    n = jnp.sum(mask.astype(jnp.int32))
    state = dict(state, valid=state["valid"] & ~mask)
    state = _tick(state)
    return state, n


_AGGS = {
    "COUNT": lambda v, m: jnp.sum(m.astype(jnp.int32)),
    "SUM": lambda v, m: jnp.sum(jnp.where(m, v, 0)),
    "MIN": lambda v, m: jnp.min(jnp.where(m, v, jnp.inf)).astype(v.dtype)
    if jnp.issubdtype(v.dtype, jnp.floating)
    else jnp.min(jnp.where(m, v, jnp.iinfo(v.dtype).max)),
    "MAX": lambda v, m: jnp.max(jnp.where(m, v, -jnp.inf)).astype(v.dtype)
    if jnp.issubdtype(v.dtype, jnp.floating)
    else jnp.max(jnp.where(m, v, jnp.iinfo(v.dtype).min)),
    "AVG": lambda v, m: jnp.sum(jnp.where(m, v.astype(jnp.float32), 0.0))
    / jnp.maximum(jnp.sum(m.astype(jnp.int32)), 1),
}


def aggregate(
    schema: TableSchema,
    state: dict,
    agg: str,
    column: str | None,
    where: P.Node | None,
    params: Sequence[Any] = (),
):
    """COUNT/SUM/MIN/MAX/AVG over the matching rows. Returns (state, value)."""
    mask = _match_mask(schema, state, where, params)
    agg = agg.upper()
    if agg == "COUNT" or column is None:
        val = _AGGS["COUNT"](None, mask)
    else:
        val = _AGGS[agg](state["cols"][column], mask)
    state = _tick(state)
    return state, val


def expire(schema: TableSchema, state: dict):
    """Automatic expiry — the paper's §4.3 conditions 1 (age) and 2 (rows).

    Condition 3 (op count) is the daemon's trigger for calling this.
    Returns (state, n_expired)."""
    pol = schema.expiry
    valid = state["valid"]
    cols = state["cols"]
    now = state["clock"].astype(jnp.int32)
    expired = jnp.zeros_like(valid)

    # 1. data age: per-row _ttl overrides the table default
    default_ttl = jnp.asarray(pol.ttl, dtype=jnp.int32)
    ttl_eff = jnp.where(cols["_ttl"] > 0, cols["_ttl"], default_ttl)
    aged = (ttl_eff > 0) & ((now - cols["_created"]) > ttl_eff)
    expired = expired | (valid & aged)

    # 2. row-count cap: keep the newest max_rows (stable tie-break by row id).
    # Overflow-safe ordering: rank rows by (created, row_id) via double
    # argsort instead of a keyed multiply (which overflows int32 clocks).
    if pol.max_rows > 0 and pol.max_rows < schema.capacity:
        cap = schema.capacity
        live = valid & ~expired
        order = jnp.lexsort((jnp.arange(cap), cols["_created"]))  # old -> new
        rank = jnp.zeros((cap,), dtype=jnp.int32).at[order].set(
            jnp.arange(cap, dtype=jnp.int32)
        )
        # rank among LIVE rows only: count live rows with strictly lower rank
        live_i = live.astype(jnp.int32)
        # cumulative live count in rank order, mapped back to row order
        live_in_rank = live_i[order]
        cum = jnp.cumsum(live_in_rank) - live_in_rank  # live rows older than me
        older_live = jnp.zeros((cap,), dtype=jnp.int32).at[order].set(cum)
        n_live = jnp.sum(live_i)
        # drop the oldest (n_live - max_rows): live rows whose "younger live
        # count" = n_live - older_live - 1 >= max_rows
        younger = n_live - older_live - 1
        drop = live & (younger >= pol.max_rows)
        expired = expired | drop

    n = jnp.sum(expired.astype(jnp.int32))
    state = dict(state, valid=valid & ~expired)
    state = _tick(state)
    return state, n


def flush(schema: TableSchema, state: dict):
    """Drop every row (memcached's only bulk invalidation mode)."""
    n = jnp.sum(state["valid"].astype(jnp.int32))
    state = dict(state, valid=jnp.zeros_like(state["valid"]))
    state = _tick(state)
    return state, n


def live_count(state: dict) -> jax.Array:
    return jnp.sum(state["valid"].astype(jnp.int32))
