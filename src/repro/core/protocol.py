"""The "web-enabling" layer: a memcached-style text protocol carrying SQL.

Faithful to the paper's §3: a daemon reachable over TCP *and* unix
sockets, line-based text protocol (in the spirit of early TCP protocols),
asynchronous connection handling with a **single execution stream** —
the cross-connection :class:`~repro.core.scheduler.BatchScheduler`
admits statements from every connection into one ordered stream and
dispatches same-shape runs as fused ``executemany`` batches (SQLcached
used poll(); we use asyncio, the modern POSIX equivalent).

Wire format (CRLF or LF tolerated; every verb optionally carries a
``#<tag>`` suffix — an opaque client token that pipelines statements):

    client:  EXEC <sql>                 -- start a statement
             EXEC#<id> <sql>            -- start a TAGGED statement
             ARG I <int>                -- bind next `?` of the most
             ARG F <float>                 recent EXEC (integer/float)
             ARG S <base64(utf-8)>      --   (text)
             ARG#<id> ...               -- bind an explicit statement
             GO / GO#<id>               -- submit for execution
             PING                       -- liveness probe
             QUIT                       -- close the connection

    server:  COUNT#<id> <n>             -- rows affected / matched
             VALUE#<id> <v>             -- aggregate result (if any; for
                                           INSERT it is the eviction count
                                           of the DISPATCH that carried
                                           the statement — a fused group
                                           reports the group total)
             ROW#<id> <json>            -- one line per returned row
             END#<id>                   -- statement finished
             ERR#<id> <message>         -- statement failed
             PONG / BYE                 -- control replies
             (untagged statements get untagged COUNT/ROW/.../ERR lines —
              the original one-round-trip-per-statement dialect)

Pipelining: a client may stream any number of tagged EXEC…GO frames
without reading; the server replies **strictly in GO-submission order**
on each connection (control replies included), so responses match up
positionally as well as by tag. Statements from all connections meet in
the batch scheduler, which fuses same-shape runs into single jitted
dispatches — this is how network clients reach the micro-batched engine.

Sharded tables ride the same wire verbatim — a client declares the
partitioning at CREATE time and every later statement is routed
transparently (core/shards.py):

    EXEC CREATE TABLE pages (site INT, id INT, hits INT, INDEX(id))
         CAPACITY 1048576 SHARDS 8 PARTITION BY site
    GO
    EXEC#1 SELECT hits FROM pages WHERE site = ? AND id = ?
    ARG#1 I 7
    ARG#1 I 123
    GO#1                      -- eq on `site` prunes to ONE shard
    EXEC#2 SELECT COUNT(*) FROM pages WHERE hits > ?
    ARG#2 I 100
    GO#2                      -- fans out, partials merge server-side
    EXEC#3 EXPLAIN SELECT hits FROM pages WHERE site = 7
    GO#3                      -- VALUE row includes "shard_route":
                              --   "pruned -> shard k" / "fan-out x 8"

Two admin statements manage the partitioning live over the same wire
(both answer with one COUNT + one VALUE line):

    EXEC SHOW STATS pages
    GO                        -- VALUE is a JSON skew report: per-shard
                              --   live_rows + statements/writes/
                              --   inserted_rows counters (a hot shard
                              --   shows up as one lane running away);
                              --   EXPLAIN pages is the same report
    EXEC ALTER TABLE pages RESHARD 16
    GO                        -- live re-partition: one bulk device-side
                              --   re-split of every live row + one
                              --   index rebuild per new shard; COUNT is
                              --   the rows moved, VALUE the new shard
                              --   count. TTL stamps ride along, so
                              --   contents round-trip exactly.
                              --   RESHARD 1 converts to monolithic.
    EXEC WARMUP pages
    GO                        -- pre-plan (AOT compile) the table's
                              --   canonical hot shapes for every placed
                              --   lane device BEFORE traffic lands;
                              --   COUNT is the executables newly
                              --   compiled, VALUE the executor-cache
                              --   epoch. WARMUP t LIKE 'SELECT ...'
                              --   pre-plans exactly the quoted shape.

Observability statements (PR 9, core/telemetry.py — all one COUNT +
one VALUE line; none ever syncs a device handle):

    EXEC SHOW METRICS pages
    GO                        -- VALUE is the JSON telemetry report:
                              --   per-(table, kind) log2 latency
                              --   histograms, p50/p99/p999, per-stage
                              --   (wire/parse/queue/lock/execute/
                              --   render) breakdowns, exec-mode and
                              --   executor-cache attribution. Omit the
                              --   table for every shape; FORMAT 'prom'
                              --   returns a Prometheus text exposition
                              --   (JSON-string-encoded: one wire line)
    EXEC EXPLAIN ANALYZE SELECT hits FROM pages WHERE site = 7
    GO                        -- executes the statement and reports its
                              --   MEASURED per-stage spans next to the
                              --   plan (admin barrier: it materializes
                              --   the inner result)
    EXEC SHOW SLOW
    GO                        -- bounded ring of span trees from
                              --   statements that crossed slow_ms
                              --   (SQLCached(slow_ms=..) /REPRO_SLOW_MS)
    EXEC SHOW STATS
    GO                        -- daemon-wide roll-up: tables, scheduler
                              --   stats, executor-cache totals, uptime

The batch scheduler additionally overlaps groups whose footprints
provably commute — different tables, disjoint columns, or pruned
statements on disjoint shard sets. Since PR 5 a sharded table's state
lives in per-shard EXECUTION LANES at the daemon: a statement group
that provably routes to one shard locks and executes only that lane,
so same-table traffic on different shards no longer queues behind one
dispatch — a hot table stops being a concurrency barrier.

Cluster tier (core/cluster.py) — the same wire, N daemons:

    EXEC CREATE TABLE pages (...) SHARDS 8 PARTITION BY site REPLICAS 2
    GO                        -- REPLICAS r is stored by every daemon and
                              --   reported by SHOW STATS; the MIRRORING
                              --   is the cluster client's job: each
                              --   write goes to the table's (or
                              --   partition slot's) r ring-successor
                              --   nodes, reads load-balance across them

A :class:`~repro.core.cluster.ClusterClient` consistent-hash-rings
tables (and ``PARTITION BY`` key slots, via ``shards.shard_of_host``)
across daemons and keeps one tagged connection per node. Three protocol
properties make failover safe, and they are guarantees of THIS layer:

- **Replay-safe tags.** A client's tag counter is monotonic across
  reconnects and every statement is fully self-contained (EXEC..ARG..GO
  frame), so an in-flight statement can be resent verbatim — to the same
  node after a reconnect or to a surviving replica — and answers match
  up by tag, never by guesswork. Writes are mirrored to every replica
  under the SAME tag, which is what makes the replay idempotent: the
  survivor already executed tag t, and its response stands in for the
  dead primary's.
- **Acknowledged = answered.** A write counts as acknowledged only once
  a COUNT/…/END (or ERR) block for its tag has been READ back — not
  when the frame was written. The cluster client acks only after every
  live replica of the statement's group has answered, so a SIGKILL of
  any one node loses zero acknowledged writes.
- **PING deadlines.** PING/PONG rides the same ordered stream, so a
  PONG proves the node's event loop is draining its queue (not merely
  that TCP connects). Health probes put a deadline on it
  (``AsyncSQLCachedClient.ping(deadline=...)``); a node that misses the
  deadline is treated exactly like a dead one — marked down, reads fail
  over to a surviving replica, which is promoted.

Connection loss is surfaced, never absorbed: the sync
:class:`Pipeline.collect` turns a dead socket into one clean
``ConnectionError`` per unanswered tag (no hangs, no silently empty
results), the async FIFO matcher fails every pending future the same
way, and both clients offer ``reconnect()`` plus configurable connect
retries with capped exponential backoff + jitter (:func:`backoff_delays`).

Tensor payloads never cross this socket — they live on the accelerator;
the protocol is the management/metadata plane (DESIGN.md §2).
"""
from __future__ import annotations

import asyncio
import base64
import itertools
import json
import random
import socket
import threading
import time
from collections import deque
from typing import Any, Sequence

from repro.core import telemetry as TEL
from repro.core.daemon import Result, SQLCached
from repro.core.scheduler import BatchScheduler

_MAX_LINE = 1 << 20
# half-assembled statements (EXEC seen, GO not yet) allowed per connection —
# bounds server memory against clients that stream EXEC#n without ever GOing
_MAX_PENDING = 256


def backoff_delays(retries: int, base: float = 0.05, cap: float = 2.0):
    """``retries`` sleep durations of capped exponential backoff with
    equal jitter: attempt k waits in [d/2, d] for d = min(cap, base*2^k).
    The jitter de-synchronizes a fleet of clients hammering a recovering
    node; the cap bounds worst-case failover latency. Shared by the
    connect paths here and every retry loop in core/cluster.py."""
    for attempt in range(retries):
        d = min(cap, base * (2.0 ** attempt))
        yield d / 2 + random.uniform(0, d / 2)


def _warmup_sql(table: str, like: str | None) -> str:
    """The WARMUP statement text for both clients' ``warmup()`` helpers
    (the quoted LIKE statement escapes ``'`` the SQL way)."""
    if like is None:
        return f"WARMUP {table}"
    return f"WARMUP {table} LIKE '" + like.replace("'", "''") + "'"


def _encode_arg(v: Any) -> str:
    if isinstance(v, bool):
        return f"ARG I {int(v)}"
    if isinstance(v, int):
        return f"ARG I {v}"
    if isinstance(v, float):
        return f"ARG F {v!r}"
    if isinstance(v, str):
        return "ARG S " + base64.b64encode(v.encode()).decode()
    raise TypeError(f"unsupported arg type {type(v)!r}")


def _decode_arg(kind: str, raw: str) -> Any:
    if kind == "I":
        return int(raw)
    if kind == "F":
        return float(raw)
    if kind == "S":
        return base64.b64decode(raw).decode()
    raise ValueError(f"bad ARG kind {kind!r}")


def _line(text: str, tag: str | None) -> bytes:
    """One response line, the verb tagged when the request was."""
    if tag is not None:
        verb, sep, rest = text.partition(" ")
        text = f"{verb}#{tag}{sep}{rest}"
    return text.encode() + b"\r\n"


def _render_result(res: Result, tag: str | None) -> bytes:
    """COUNT/VALUE/ROW.../END block for one Result. Forces the lazy
    device→host sync — call off the event loop."""
    sfx = "" if tag is None else f"#{tag}"
    out = [f"COUNT{sfx} {res.count}".encode()]
    if res.value is not None:
        out.append(f"VALUE{sfx} {res.value}".encode())
    for row in res.rows or []:
        out.append(f"ROW{sfx} ".encode() + json.dumps(row).encode())
    out.append(f"END{sfx}".encode())
    return b"\r\n".join(out) + b"\r\n"


def _render_burst(items: list) -> tuple[bytes, int, int, list]:
    """Render a burst of resolved responses in ONE worker-thread hop:
    ``items`` holds (tag, Result | Exception | str, trace) in response
    order. Returns (wire bytes, n statements ok, n statement errors,
    [trace] for traced items, ``trace.error`` stamped). Sibling Results of one batch
    share a device→host sync here, and each statement's trace gets its
    "render" span stamped at render time — but the histogram fold
    (``Telemetry.finish``) is the CALLER's job, after the bytes are on
    the socket, so recording never adds to the client-visible latency."""
    parts: list[bytes] = []
    stmts = errs = 0
    done: list = []
    for tag, payload, trace in items:
        err = False
        if isinstance(payload, Exception):
            msg = str(payload).replace("\n", " ")[:500]
            parts.append(_line(f"ERR {msg}", tag))
            errs += 1
            err = True
        elif isinstance(payload, str):
            parts.append(_line(payload, tag))
        else:
            try:
                parts.append(_render_result(payload, tag))
                stmts += 1
            except Exception as e:  # noqa: BLE001
                msg = str(e).replace("\n", " ")[:500]
                parts.append(_line(f"ERR {msg}", tag))
                errs += 1
                err = True
        if trace is not None:
            trace.mark("render")
            if err:
                trace.error = True
            done.append(trace)
    return b"".join(parts), stmts, errs, done


class _LineTooLong(Exception):
    """Raised once per oversized line; ``prefix`` preserves the line's
    first bytes so the handler can still identify the verb and tag and
    answer the right statement."""

    def __init__(self, prefix: bytes = b""):
        super().__init__("line too long")
        self.prefix = prefix


class _LineReader:
    """Own line framing on top of ``StreamReader.read``.

    asyncio's ``readline`` raises ``ValueError`` once a line passes the
    stream limit and loses buffered bytes past the separator when you try
    to recover; we keep our own buffer so an oversized line is skipped
    *exactly* (→ one ``ERR line too long``) and the connection survives.
    """

    def __init__(self, reader: asyncio.StreamReader, max_line: int = _MAX_LINE):
        self._r = reader
        self._max = max_line
        self._buf = bytearray()
        self._skip = False

    async def readline(self) -> bytes | None:
        """Next line without its terminator; None on EOF. Raises
        :class:`_LineTooLong` once per oversized line."""
        while True:
            i = self._buf.find(b"\n")
            if i >= 0:
                skipped, self._skip = self._skip, False
                too_long = i > self._max
                line = b"" if (skipped or too_long) else bytes(self._buf[:i])
                prefix = bytes(self._buf[:128]) if too_long else b""
                del self._buf[: i + 1]
                if skipped:
                    continue  # tail of an already-reported oversized line
                if too_long:
                    raise _LineTooLong(prefix)
                return line.rstrip(b"\r")
            if self._skip:
                del self._buf[:]
            elif len(self._buf) > self._max:
                prefix = bytes(self._buf[:128])
                del self._buf[:]
                self._skip = True
                raise _LineTooLong(prefix)
            chunk = await self._r.read(65536)
            if not chunk:
                if self._buf and not self._skip:
                    line = bytes(self._buf).rstrip(b"\r")
                    del self._buf[:]
                    if len(line) > self._max:
                        raise _LineTooLong(line[:128])
                    return line
                return None
            self._buf += chunk


class _ResponseQueue:
    """Per-connection ordered response flusher.

    Every reply — immediate control replies and lazy statement futures
    alike — enters ONE FIFO and is written strictly in submission order,
    so pipelined clients can match responses positionally. Statement
    rendering (which syncs the lazy Result) runs in a worker thread, off
    the event loop. This per-connection ordering is what replaced the old
    global ``_exec_lock``."""

    def __init__(self, writer: asyncio.StreamWriter, server: "SQLCachedServer"):
        self._writer = writer
        self._server = server
        self._telemetry = server.db.telemetry
        self._ring = self._telemetry.ring()  # per-connection trace ring
        self._q: asyncio.Queue = asyncio.Queue(maxsize=1024)
        self._task = asyncio.create_task(self._run())

    async def put_raw(self, tag: str | None, text: str) -> None:
        if text.startswith("ERR"):
            self._server.stats.add("errors")
        await self._q.put((tag, text, None))

    async def put_future(self, tag: str | None, fut: asyncio.Future,
                         trace: "TEL.Trace | None" = None) -> None:
        await self._q.put((tag, fut, trace))

    async def _run(self) -> None:
        closing = False
        while not closing:
            burst = [await self._q.get()]
            while not self._q.empty() and len(burst) < 64:
                burst.append(self._q.get_nowait())
            # resolve in order (responses must flush in submission order,
            # so waiting on the head future never reorders anything)
            items: list[tuple[str | None, Any, Any]] = []
            for entry in burst:
                if entry is None:
                    closing = True
                    break
                tag, payload, trace = entry
                if isinstance(payload, asyncio.Future):
                    try:
                        items.append((tag, await payload, trace))
                    except asyncio.CancelledError:
                        raise
                    except Exception as e:  # noqa: BLE001
                        items.append((tag, e, trace))
                else:
                    items.append((tag, payload, trace))
            if not items:
                continue
            try:
                data, stmts, errs, done = await asyncio.to_thread(
                    _render_burst, items)
                self._server.stats.add("statements", stmts)
                self._server.stats.add("errors", errs)
                self._writer.write(data)
                await self._writer.drain()
                # trace hand-off AFTER the response is on the wire:
                # finish() is an O(1) enqueue — the histogram fold runs
                # in telemetry's background folder thread, never here
                for trace in done:
                    self._telemetry.finish(trace, ring=self._ring,
                                           error=trace.error)
            except (ConnectionError, OSError):
                # peer went away mid-write. Keep CONSUMING until the close
                # sentinel — the handler may be parked on the bounded
                # put() and must not deadlock — and retrieve future
                # exceptions so they don't surface as asyncio warnings.
                while True:
                    item = await self._q.get()
                    if item is None:
                        return
                    payload = item[1]
                    if isinstance(payload, asyncio.Future):
                        try:
                            await payload
                        except Exception:  # noqa: BLE001
                            pass

    async def close(self) -> None:
        await self._q.put(None)
        try:
            await self._task
        except asyncio.CancelledError:
            pass


class SQLCachedServer:
    """Asyncio daemon wrapping one SQLCached store.

    ``serve_forever`` listens on TCP and/or a unix socket. Connection
    handling is async; statements from every connection are admitted
    into the :class:`~repro.core.scheduler.BatchScheduler`, which fuses
    same-shape runs into single ``executemany`` dispatches while per-
    connection response queues flush the lazy Results in submission
    order. ``batching=False`` keeps the single execution stream strictly
    per-statement (the paper's original regime)."""

    def __init__(self, db: SQLCached | None = None, *, batching: bool = True,
                 max_batch: int = 64, max_wait_us: int = 0):
        self.db = db or SQLCached()
        self.scheduler = BatchScheduler(self.db, batching=batching,
                                        max_batch=max_batch,
                                        max_wait_us=max_wait_us)
        self._servers: list[asyncio.AbstractServer] = []
        self._conn_tasks: set[asyncio.Task] = set()
        # atomic (telemetry.Counters): render worker threads and the
        # event loop both increment these
        self.stats = TEL.Counters({"connections": 0, "statements": 0,
                                   "errors": 0})
        # register live stats for the SHOW STATS daemon-wide roll-up
        self.db.telemetry.attach("scheduler", self.scheduler.stats)
        self.db.telemetry.attach("server", self.stats)

    # ------------------------------------------------------------ lifecycle
    async def start(
        self,
        host: str | None = "127.0.0.1",
        port: int | None = 0,
        unix_path: str | None = None,
    ) -> tuple[str, int] | None:
        await self.scheduler.start()
        addr = None
        if host is not None and port is not None:
            srv = await asyncio.start_server(self._handle, host, port,
                                             limit=_MAX_LINE)
            self._servers.append(srv)
            addr = srv.sockets[0].getsockname()[:2]
        if unix_path is not None:
            srv = await asyncio.start_unix_server(self._handle, unix_path,
                                                  limit=_MAX_LINE)
            self._servers.append(srv)
        return addr

    async def stop(self) -> None:
        for srv in self._servers:
            srv.close()
            await srv.wait_closed()
        self._servers.clear()
        for t in list(self._conn_tasks):
            t.cancel()
        if self._conn_tasks:
            await asyncio.gather(*self._conn_tasks, return_exceptions=True)
        await self.scheduler.stop()

    # ------------------------------------------------------------- protocol
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        self.stats.add("connections")
        task = asyncio.current_task()
        if task is not None:
            self._conn_tasks.add(task)
        resp = _ResponseQueue(writer, self)
        lines = _LineReader(reader)
        # statements being assembled, keyed by tag (None = untagged);
        # `cur` is the most recent EXEC's tag — untagged ARG/GO bind to
        # it. Each entry carries the trace stamped at EXEC receipt.
        pending: dict[str | None, tuple[str, list, Any]] = {}
        cur: str | None = None
        # response invariant: every submitted statement gets EXACTLY ONE
        # response block, or pipelined clients desync. A statement that
        # already drew its ERR (too-long line, bad ARG, pending-cap
        # rejection) must have its remaining ARG/GO lines swallowed:
        # `dropped` covers the known-tag cases; `poisoned` covers an
        # untagged dropped line and swallows only UNTAGGED ARG/GO (tagged
        # lines always belong to an identifiable statement).
        poisoned = False
        dropped: set[str | None] = set()

        def _mark_dropped(key: str | None) -> bool:
            """False when the drop-tracking budget is exhausted (protocol
            abuse) — the caller must close the connection rather than
            risk emitting a second response for a statement."""
            if len(dropped) >= _MAX_PENDING:
                return False
            dropped.add(key)
            return True

        try:
            while True:
                try:
                    line = await lines.readline()
                except _LineTooLong as tl:
                    head = tl.prefix.decode("utf-8", "replace")
                    hverb, _, _ = head.partition(" ")
                    hverb, _, htag = hverb.partition("#")
                    hverb = hverb.upper()
                    htag = htag or None
                    if hverb in ("EXEC", "ARG", "GO"):
                        # the oversized line's statement is identifiable
                        # (its tag, or — for an untagged ARG/GO — the most
                        # recent EXEC): answer THAT statement once and
                        # retire it; cur moves onto the dropped key so its
                        # remaining untagged ARG/GO lines are swallowed
                        key = htag if htag is not None else (
                            None if hverb == "EXEC" else cur)
                        pending.pop(key, None)
                        if hverb != "GO":
                            if not _mark_dropped(key):
                                await resp.put_raw(None,
                                                   "ERR pipeline abuse")
                                break
                            cur = key
                        await resp.put_raw(key, "ERR line too long")
                    else:
                        await resp.put_raw(None, "ERR line too long")
                        poisoned = True
                    continue
                if line is None:
                    break
                text = line.decode("utf-8", "replace")
                if not text:
                    continue
                verb, _, rest = text.partition(" ")
                verb, _, tag = verb.partition("#")
                verb = verb.upper()
                tag = tag or None
                if verb == "EXEC":
                    poisoned = False
                    dropped.discard(tag)
                    if tag not in pending and len(pending) >= _MAX_PENDING:
                        await resp.put_raw(
                            tag, "ERR too many in-flight statements")
                        if not _mark_dropped(tag):
                            await resp.put_raw(None, "ERR pipeline abuse")
                            break
                        cur = tag
                        continue
                    pending[tag] = (rest, [], self.db.telemetry.trace())
                    cur = tag
                elif verb == "ARG":
                    if poisoned and tag is None:
                        continue
                    key = tag if tag is not None else cur
                    if key in dropped:
                        continue  # statement already answered with ERR
                    st = pending.get(key)
                    if st is None:
                        await resp.put_raw(key, "ERR ARG without EXEC")
                        continue
                    kind, _, raw = rest.partition(" ")
                    try:
                        st[1].append(_decode_arg(kind, raw))
                    except Exception as e:  # noqa: BLE001
                        # drop the whole half-bound statement — its later
                        # ARGs and its GO are swallowed, so the ONE error
                        # response keeps the pipeline in sync
                        pending.pop(key, None)
                        if not _mark_dropped(key):
                            await resp.put_raw(None, "ERR pipeline abuse")
                            break
                        await resp.put_raw(key, f"ERR bad arg: {e}")
                elif verb == "GO":
                    if poisoned and tag is None:
                        poisoned = False
                        continue
                    key = tag if tag is not None else cur
                    if key in dropped:
                        dropped.discard(key)
                        continue  # statement already answered with ERR
                    st = pending.pop(key, None)
                    if st is None or not st[0]:
                        await resp.put_raw(key, "ERR no statement")
                        continue
                    fut = self.scheduler.submit(st[0], st[1], trace=st[2])
                    await resp.put_future(key, fut, st[2])
                elif verb == "PING":
                    await resp.put_raw(tag, "PONG")
                elif verb == "QUIT":
                    await resp.put_raw(tag, "BYE")
                    break
                else:
                    await resp.put_raw(tag, f"ERR unknown verb {verb!r}")
        finally:
            try:
                await resp.close()
            except asyncio.CancelledError:
                resp._task.cancel()
            writer.close()
            try:
                await writer.wait_closed()
            except BaseException:  # noqa: BLE001 — incl. CancelledError
                pass
            if task is not None:
                self._conn_tasks.discard(task)


class SQLCachedClient:
    """Small synchronous client (what a web app's cache layer would embed).

    ``execute`` keeps the original one-round-trip-per-statement dialect;
    :meth:`pipeline` opens a tagged pipeline that streams statements
    without waiting and collects all responses at once."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 unix_path: str | None = None, timeout: float = 10.0,
                 connect_retries: int = 0, retry_base: float = 0.05,
                 retry_cap: float = 2.0):
        self._host, self._port = host, port
        self._unix_path = unix_path
        self._timeout = timeout
        self._connect_retries = connect_retries
        self._retry_base, self._retry_cap = retry_base, retry_cap
        self._sock = self._connect()
        self._buf = b""
        self._tag = 0

    def _connect(self) -> socket.socket:
        """Dial with up to ``connect_retries`` retries (capped exponential
        backoff + jitter) — a daemon that is still booting, or restarting
        after a crash, stops being the caller's race to lose."""
        last: Exception | None = None
        for delay in itertools.chain(
                [None], backoff_delays(self._connect_retries,
                                       self._retry_base, self._retry_cap)):
            if delay is not None:
                time.sleep(delay)
            try:
                if self._unix_path is not None:
                    s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                    s.settimeout(self._timeout)
                    s.connect(self._unix_path)
                else:
                    s = socket.create_connection(
                        (self._host, self._port), timeout=self._timeout)
                s.settimeout(self._timeout)
                return s
            except OSError as e:
                last = e
        where = (self._unix_path if self._unix_path is not None
                 else f"{self._host}:{self._port}")
        raise ConnectionError(
            f"could not connect to {where} after "
            f"{self._connect_retries + 1} attempt(s): {last}")

    def reconnect(self) -> None:
        """Re-establish a dropped connection IN PLACE: fresh socket, empty
        read buffer, same client object — callers keep their handle
        instead of rebuilding. Responses in flight on the old socket are
        gone (resend their statements); the tag counter keeps rising so
        replayed statements stay distinguishable from new ones."""
        try:
            self._sock.close()
        except OSError:
            pass
        self._sock = self._connect()
        self._buf = b""

    def _next_tag(self) -> str:
        self._tag += 1
        return str(self._tag)

    def _readline(self) -> str:
        while b"\n" not in self._buf:
            chunk = self._sock.recv(65536)
            if not chunk:
                raise ConnectionError("server closed connection")
            self._buf += chunk
        line, _, self._buf = self._buf.partition(b"\n")
        return line.decode().rstrip("\r")

    def _read_result(self, tag: str | None = None) -> dict:
        """Read one COUNT/VALUE/ROW.../END response block. ``tag`` is the
        expected response tag (None = untagged). Stray control lines
        (PONG/BYE), mismatched tags and unknown verbs raise — a desynced
        connection must never masquerade as a successful empty result."""
        result: dict = {"count": 0, "value": None, "rows": []}
        while True:
            line = self._readline()
            verb, _, rest = line.partition(" ")
            verb, _, rtag = verb.partition("#")
            rtag = rtag or None
            if verb in ("COUNT", "VALUE", "ROW", "END", "ERR") and rtag != tag:
                raise RuntimeError(
                    f"protocol desync: expected tag {tag!r}, got {line!r}")
            if verb == "COUNT":
                result["count"] = int(rest)
            elif verb == "VALUE":
                try:
                    result["value"] = json.loads(rest)
                except json.JSONDecodeError:
                    result["value"] = rest
            elif verb == "ROW":
                result["rows"].append(json.loads(rest))
            elif verb == "END":
                return result
            elif verb == "ERR":
                raise RuntimeError(f"server error: {rest}")
            else:
                raise RuntimeError(f"protocol desync: unexpected {line!r}")

    def execute(self, sql: str, params: Sequence[Any] = ()) -> dict:
        out = [f"EXEC {sql}"]
        out += [_encode_arg(p) for p in params]
        out.append("GO")
        self._sock.sendall(("\r\n".join(out) + "\r\n").encode())
        return self._read_result(None)

    def warmup(self, table: str, like: str | None = None) -> dict:
        """Pre-plan ``table``'s executors server-side (``WARMUP t [LIKE
        '<stmt>']``): count = newly compiled executables."""
        return self.execute(_warmup_sql(table, like))

    def pipeline(self) -> "Pipeline":
        """Open a client-side pipeline (usable as a context manager —
        leaving the ``with`` block collects into ``.results``)."""
        return Pipeline(self)

    def ping(self) -> bool:
        self._sock.sendall(b"PING\r\n")
        return self._readline() == "PONG"

    def close(self) -> None:
        try:
            self._sock.sendall(b"QUIT\r\n")
        except OSError:
            pass
        self._sock.close()


class Pipeline:
    """Client-side pipelining over the tagged dialect: queue statements
    without waiting, flush them in one write, then :meth:`collect` all
    responses in submission order (the server guarantees that order)."""

    def __init__(self, client: SQLCachedClient):
        self._c = client
        self._out: list[str] = []
        self._tags: list[str] = []
        self.results: list = []

    def __len__(self) -> int:
        return len(self._tags)

    def execute(self, sql: str, params: Sequence[Any] = ()) -> int:
        """Queue one statement; returns its index into :meth:`collect`'s
        result list."""
        tag = self._c._next_tag()
        self._out.append(f"EXEC#{tag} {sql}")
        self._out += [_encode_arg(p) for p in params]
        self._out.append(f"GO#{tag}")
        self._tags.append(tag)
        return len(self._tags) - 1

    def flush(self) -> None:
        """Stream every queued frame to the server without reading."""
        if self._out:
            self._c._sock.sendall(("\r\n".join(self._out) + "\r\n").encode())
            self._out.clear()

    def collect(self, return_exceptions: bool = False) -> list:
        """Flush, then read one response per queued statement, in order.
        Statement errors become RuntimeError entries (``return_exceptions=
        True``) or raise after the whole pipeline has drained. A dying
        server becomes one clean ``ConnectionError`` PER unanswered tag —
        never a hang, never a silently short result list: the result list
        always has exactly one entry per queued statement."""
        self.flush()
        out: list = []
        errs: list[Exception] = []
        for i, tag in enumerate(self._tags):
            try:
                out.append(self._c._read_result(tag))
            except RuntimeError as e:
                out.append(e)
                errs.append(e)
            except OSError as e:  # incl. ConnectionError / socket.timeout
                # dead socket: no later tag can be answered either — fail
                # this one and every still-queued statement, each with its
                # own entry, so positional matching survives the crash
                for t2 in self._tags[i:]:
                    ce = ConnectionError(
                        f"connection lost before response for tag {t2}: {e}")
                    out.append(ce)
                    errs.append(ce)
                break
        self._tags.clear()
        self.results = out
        if errs and not return_exceptions:
            raise errs[0]
        return out

    def __enter__(self) -> "Pipeline":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.collect(return_exceptions=True)


class AsyncSQLCachedClient:
    """Asyncio client speaking the tagged dialect.

    ``execute`` coroutines may be issued concurrently (``gather``) — each
    statement streams out immediately and its future resolves when the
    tagged response arrives, so N outstanding statements cost one round
    trip instead of N. Responses arrive in per-connection submission
    order; a background reader task matches them to the FIFO of pending
    futures (tags are verified, desync fails every pending call)."""

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter):
        self._r = reader
        self._w = writer
        self._tag = 0
        self._fifo: deque[tuple[str | None, asyncio.Future]] = deque()
        self._reader_task = asyncio.create_task(self._read_loop())
        # set by connect(); reconnect() needs it to re-dial
        self._dial: tuple[str, int, str | None] | None = None

    @classmethod
    async def connect(cls, host: str = "127.0.0.1", port: int = 0,
                      unix_path: str | None = None,
                      connect_retries: int = 0, retry_base: float = 0.05,
                      retry_cap: float = 2.0) -> "AsyncSQLCachedClient":
        """Dial with up to ``connect_retries`` retries (capped exponential
        backoff + jitter, like the sync client's)."""
        r, w = await cls._dial_streams(host, port, unix_path,
                                       connect_retries, retry_base,
                                       retry_cap)
        c = cls(r, w)
        c._dial = (host, port, unix_path)
        return c

    @staticmethod
    async def _dial_streams(host, port, unix_path, connect_retries,
                            retry_base, retry_cap):
        last: Exception | None = None
        for delay in itertools.chain(
                [None],
                backoff_delays(connect_retries, retry_base, retry_cap)):
            if delay is not None:
                await asyncio.sleep(delay)
            try:
                if unix_path is not None:
                    return await asyncio.open_unix_connection(unix_path)
                return await asyncio.open_connection(host, port)
            except OSError as e:
                last = e
        where = unix_path if unix_path is not None else f"{host}:{port}"
        raise ConnectionError(
            f"could not connect to {where} after "
            f"{connect_retries + 1} attempt(s): {last}")

    async def reconnect(self, connect_retries: int = 0,
                        retry_base: float = 0.05,
                        retry_cap: float = 2.0) -> None:
        """Re-establish a dropped connection IN PLACE (clients built via
        :meth:`connect` only). Every still-pending future fails with
        ``ConnectionError`` first — their responses died with the old
        socket; resend those statements. The tag counter keeps rising so
        replays stay distinguishable."""
        if self._dial is None:
            raise RuntimeError("reconnect() needs a client built by "
                               "AsyncSQLCachedClient.connect()")
        self._reader_task.cancel()
        try:
            await self._reader_task
        except asyncio.CancelledError:
            pass
        self._w.close()
        try:
            await self._w.wait_closed()
        except Exception:  # noqa: BLE001
            pass
        host, port, unix_path = self._dial
        self._r, self._w = await self._dial_streams(
            host, port, unix_path, connect_retries, retry_base, retry_cap)
        self._reader_task = asyncio.create_task(self._read_loop())

    async def execute(self, sql: str, params: Sequence[Any] = ()) -> dict:
        self._check_alive()
        self._tag += 1
        tag = str(self._tag)
        lines = [f"EXEC#{tag} {sql}"]
        lines += [_encode_arg(p) for p in params]
        lines.append(f"GO#{tag}")
        fut = asyncio.get_running_loop().create_future()
        self._fifo.append((tag, fut))
        self._w.write(("\r\n".join(lines) + "\r\n").encode())
        await self._w.drain()
        return await fut

    async def warmup(self, table: str, like: str | None = None) -> dict:
        """Pre-plan ``table``'s executors server-side (``WARMUP t [LIKE
        '<stmt>']``): count = newly compiled executables."""
        return await self.execute(_warmup_sql(table, like))

    async def ping(self, deadline: float | None = None) -> bool:
        """Liveness probe. With ``deadline`` (seconds) a late PONG raises
        ``TimeoutError`` — the health-check contract: the PONG rides the
        ordered response stream, so meeting the deadline proves the
        node's event loop is draining its queue, not merely that TCP
        still connects. A node that misses its deadline is treated by
        the cluster tier exactly like a dead one."""
        self._check_alive()
        fut = asyncio.get_running_loop().create_future()
        self._fifo.append((None, fut))
        self._w.write(b"PING\r\n")
        await self._w.drain()
        if deadline is None:
            return await fut
        return await asyncio.wait_for(fut, deadline)

    def _check_alive(self) -> None:
        """Fail fast once the read loop has exited: a half-closed peer
        (FIN received, our write side still open) would otherwise accept
        the statement bytes and leave the response future pending
        forever. No await between this check and the fifo append, so the
        read loop's drain-on-exit can never miss the new entry."""
        if self._reader_task.done():
            raise ConnectionError(
                "connection lost (reader exited); reconnect() to resume")

    async def _read_loop(self) -> None:
        cur: dict | None = None
        err: Exception = ConnectionError("server closed connection")
        try:
            while True:
                raw = await self._r.readline()
                if not raw:
                    break
                text = raw.decode("utf-8", "replace").rstrip("\r\n")
                if not text:
                    continue
                verb, _, rest = text.partition(" ")
                verb, _, rtag = verb.partition("#")
                rtag = rtag or None
                if verb == "BYE":
                    break
                head = self._fifo[0] if self._fifo else None
                if verb == "PONG":
                    if head is None or head[0] is not None:
                        raise RuntimeError(f"protocol desync: stray {text!r}")
                    self._fifo.popleft()
                    if not head[1].done():
                        head[1].set_result(True)
                    continue
                if head is None or head[0] != rtag:
                    raise RuntimeError(
                        f"protocol desync: unexpected {text!r}")
                if cur is None:
                    cur = {"count": 0, "value": None, "rows": []}
                if verb == "COUNT":
                    cur["count"] = int(rest)
                elif verb == "VALUE":
                    try:
                        cur["value"] = json.loads(rest)
                    except json.JSONDecodeError:
                        cur["value"] = rest
                elif verb == "ROW":
                    cur["rows"].append(json.loads(rest))
                elif verb == "END":
                    self._fifo.popleft()
                    if not head[1].done():
                        head[1].set_result(cur)
                    cur = None
                elif verb == "ERR":
                    self._fifo.popleft()
                    if not head[1].done():
                        head[1].set_exception(
                            RuntimeError(f"server error: {rest}"))
                    cur = None
                else:
                    raise RuntimeError(f"protocol desync: unexpected {text!r}")
        except Exception as e:  # noqa: BLE001
            err = e
        finally:
            while self._fifo:
                _, fut = self._fifo.popleft()
                if not fut.done():
                    fut.set_exception(err)

    async def close(self) -> None:
        try:
            self._w.write(b"QUIT\r\n")
            await self._w.drain()
        except (ConnectionError, OSError):
            pass
        try:
            await asyncio.wait_for(self._reader_task, timeout=5)
        except (asyncio.TimeoutError, asyncio.CancelledError):
            self._reader_task.cancel()
        self._w.close()
        try:
            await self._w.wait_closed()
        except Exception:  # noqa: BLE001
            pass


class ThreadedServer:
    """Run an :class:`SQLCachedServer` on its own event-loop thread —
    for synchronous tests, benchmarks and embedding in non-async apps.
    Usable as a context manager; ``addr`` is the TCP (host, port)."""

    def __init__(self, unix_path: str | None = None, host: str = "127.0.0.1",
                 port: int = 0, db: SQLCached | None = None, **server_kw):
        self.unix_path = unix_path
        self.addr: tuple[str, int] | None = None
        self.server: SQLCachedServer | None = None
        self._host, self._port = host, port
        self._db, self._server_kw = db, server_kw
        self._loop: asyncio.AbstractEventLoop | None = None
        self._boot_error: BaseException | None = None
        self._started = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        if not self._started.wait(10):
            raise RuntimeError("server thread did not start in 10 s")
        if self._boot_error is not None:
            self._thread.join(5)
            raise self._boot_error

    def _run(self) -> None:
        self._loop = asyncio.new_event_loop()
        asyncio.set_event_loop(self._loop)
        self.server = SQLCachedServer(self._db, **self._server_kw)

        async def boot():
            try:
                self.addr = await self.server.start(
                    self._host, self._port, unix_path=self.unix_path)
            except BaseException as e:  # noqa: BLE001 — rethrown in __init__
                self._boot_error = e
            finally:
                self._started.set()

        self._loop.run_until_complete(boot())
        if self._boot_error is None:
            self._loop.run_forever()

    def stop(self) -> None:
        async def down():
            await self.server.stop()

        asyncio.run_coroutine_threadsafe(down(), self._loop).result(10)
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(10)

    def __enter__(self) -> "ThreadedServer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()


def run_server_forever(host: str, port: int, unix_path: str | None = None,
                       db: SQLCached | None = None) -> None:
    """Blocking entry point (used by `python -m repro.core.protocol`)."""

    async def main():
        server = SQLCachedServer(db)
        addr = await server.start(host, port, unix_path)
        # machine-readable + flushed: the cluster launcher and the chaos
        # harness spawn daemons with --port 0 and parse the bound port
        if addr is not None:
            # reprolint: disable=REP005(startup handshake: cluster_up and the chaos harness parse the bound port from stdout)
            print(f"SQLCACHED READY {addr[0]} {addr[1]}", flush=True)
        else:
            # reprolint: disable=REP005(startup handshake: cluster_up and the chaos harness parse the socket path from stdout)
            print(f"SQLCACHED READY unix {unix_path}", flush=True)
        # reprolint: disable=REP005(one-shot operator banner at startup, not on the serving path)
        print(f"sqlcached listening on {addr} unix={unix_path}", flush=True)
        await asyncio.Event().wait()

    asyncio.run(main())


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=11222)
    ap.add_argument("--unix", default=None)
    a = ap.parse_args()
    run_server_forever(a.host, a.port, a.unix)
