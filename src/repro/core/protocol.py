"""The "web-enabling" layer: a memcached-style text protocol carrying SQL.

Faithful to the paper's §3: a daemon reachable over TCP *and* unix
sockets, line-based text protocol (in the spirit of early TCP protocols),
asynchronous connection handling with a **single execution stream** —
at any moment only one request is being executed against the store
(SQLcached used poll(); we use asyncio, the modern POSIX equivalent).

Wire format (CRLF or LF tolerated):

    client:  EXEC <sql>                 -- start a statement
             ARG I <int>                -- bind next `?` (integer)
             ARG F <float>              --   (float)
             ARG S <base64(utf-8)>      --   (text)
             GO                         -- execute

    server:  COUNT <n>                  -- rows affected / matched
             VALUE <v>                  -- aggregate result (if any)
             ROW <json>                 -- one line per returned row
             END                        -- statement finished
             ERR <message>              -- on any failure

Tensor payloads never cross this socket — they live on the accelerator;
the protocol is the management/metadata plane (DESIGN.md §2).
"""
from __future__ import annotations

import asyncio
import base64
import json
import socket
from typing import Any, Sequence

from repro.core.daemon import Result, SQLCached

_MAX_LINE = 1 << 20


def _encode_arg(v: Any) -> str:
    if isinstance(v, bool):
        return f"ARG I {int(v)}"
    if isinstance(v, int):
        return f"ARG I {v}"
    if isinstance(v, float):
        return f"ARG F {v!r}"
    if isinstance(v, str):
        return "ARG S " + base64.b64encode(v.encode()).decode()
    raise TypeError(f"unsupported arg type {type(v)!r}")


def _decode_arg(kind: str, raw: str) -> Any:
    if kind == "I":
        return int(raw)
    if kind == "F":
        return float(raw)
    if kind == "S":
        return base64.b64decode(raw).decode()
    raise ValueError(f"bad ARG kind {kind!r}")


class SQLCachedServer:
    """Asyncio daemon wrapping one SQLCached store.

    ``serve_forever`` listens on TCP and/or a unix socket. Connection
    handling is async; statement execution is serialized through
    ``self._exec_lock`` (single execution stream, as in the paper).
    """

    def __init__(self, db: SQLCached | None = None):
        self.db = db or SQLCached()
        self._exec_lock = asyncio.Lock()
        self._servers: list[asyncio.AbstractServer] = []
        self.stats = {"connections": 0, "statements": 0, "errors": 0}

    # ------------------------------------------------------------ lifecycle
    async def start(
        self,
        host: str | None = "127.0.0.1",
        port: int | None = 0,
        unix_path: str | None = None,
    ) -> tuple[str, int] | None:
        addr = None
        if host is not None and port is not None:
            srv = await asyncio.start_server(self._handle, host, port)
            self._servers.append(srv)
            addr = srv.sockets[0].getsockname()[:2]
        if unix_path is not None:
            srv = await asyncio.start_unix_server(self._handle, unix_path)
            self._servers.append(srv)
        return addr

    async def stop(self) -> None:
        for srv in self._servers:
            srv.close()
            await srv.wait_closed()
        self._servers.clear()

    # ------------------------------------------------------------- protocol
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        self.stats["connections"] += 1
        sql: str | None = None
        args: list[Any] = []
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                if len(line) > _MAX_LINE:
                    writer.write(b"ERR line too long\r\n")
                    break
                text = line.decode("utf-8", "replace").rstrip("\r\n")
                if not text:
                    continue
                verb, _, rest = text.partition(" ")
                verb = verb.upper()
                if verb == "EXEC":
                    sql, args = rest, []
                elif verb == "ARG":
                    kind, _, raw = rest.partition(" ")
                    try:
                        args.append(_decode_arg(kind, raw))
                    except Exception as e:  # noqa: BLE001
                        writer.write(f"ERR bad arg: {e}\r\n".encode())
                        sql = None
                elif verb == "GO":
                    await self._run(sql, args, writer)
                    sql, args = None, []
                elif verb == "PING":
                    writer.write(b"PONG\r\n")
                elif verb == "QUIT":
                    writer.write(b"BYE\r\n")
                    break
                else:
                    writer.write(f"ERR unknown verb {verb!r}\r\n".encode())
                await writer.drain()
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except Exception:  # noqa: BLE001
                pass

    async def _run(self, sql: str | None, args: list[Any],
                   writer: asyncio.StreamWriter) -> None:
        if not sql:
            writer.write(b"ERR no statement\r\n")
            self.stats["errors"] += 1
            return
        async with self._exec_lock:  # single execution stream
            try:
                res: Result = await asyncio.to_thread(self.db.execute, sql, args)
            except Exception as e:  # noqa: BLE001
                self.stats["errors"] += 1
                msg = str(e).replace("\n", " ")[:500]
                writer.write(f"ERR {msg}\r\n".encode())
                return
        self.stats["statements"] += 1
        writer.write(f"COUNT {res.count}\r\n".encode())
        if res.value is not None:
            writer.write(f"VALUE {res.value}\r\n".encode())
        for row in res.rows or []:
            writer.write(b"ROW " + json.dumps(row).encode() + b"\r\n")
        writer.write(b"END\r\n")


class SQLCachedClient:
    """Small synchronous client (what a web app's cache layer would embed)."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 unix_path: str | None = None, timeout: float = 10.0):
        if unix_path is not None:
            self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            self._sock.connect(unix_path)
        else:
            self._sock = socket.create_connection((host, port), timeout=timeout)
        self._sock.settimeout(timeout)
        self._buf = b""

    def _readline(self) -> str:
        while b"\n" not in self._buf:
            chunk = self._sock.recv(65536)
            if not chunk:
                raise ConnectionError("server closed connection")
            self._buf += chunk
        line, _, self._buf = self._buf.partition(b"\n")
        return line.decode().rstrip("\r")

    def execute(self, sql: str, params: Sequence[Any] = ()) -> dict:
        out = [f"EXEC {sql}"]
        out += [_encode_arg(p) for p in params]
        out.append("GO")
        self._sock.sendall(("\r\n".join(out) + "\r\n").encode())
        result: dict = {"count": 0, "value": None, "rows": []}
        while True:
            line = self._readline()
            verb, _, rest = line.partition(" ")
            if verb == "COUNT":
                result["count"] = int(rest)
            elif verb == "VALUE":
                try:
                    result["value"] = json.loads(rest)
                except json.JSONDecodeError:
                    result["value"] = rest
            elif verb == "ROW":
                result["rows"].append(json.loads(rest))
            elif verb == "END":
                return result
            elif verb == "ERR":
                raise RuntimeError(f"server error: {rest}")
            elif verb in ("PONG", "BYE"):
                return result
            else:
                raise RuntimeError(f"bad server line: {line!r}")

    def ping(self) -> bool:
        self._sock.sendall(b"PING\r\n")
        return self._readline() == "PONG"

    def close(self) -> None:
        try:
            self._sock.sendall(b"QUIT\r\n")
        except OSError:
            pass
        self._sock.close()


def run_server_forever(host: str, port: int, unix_path: str | None = None,
                       db: SQLCached | None = None) -> None:
    """Blocking entry point (used by `python -m repro.core.protocol`)."""

    async def main():
        server = SQLCachedServer(db)
        addr = await server.start(host, port, unix_path)
        print(f"sqlcached listening on {addr} unix={unix_path}")
        await asyncio.Event().wait()

    asyncio.run(main())


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=11222)
    ap.add_argument("--unix", default=None)
    a = ap.parse_args()
    run_server_forever(a.host, a.port, a.unix)
