"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local:global attention, 128k context, QK-norm,
sandwich norms, head_dim=128 [hf:google/gemma-3]."""
import jax.numpy as jnp

from repro.models.config import ModelConfig, pattern_local_global

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,               # decoupled from d_model/n_heads
    d_ff=21504,
    vocab=262144,
    vocab_pad_to=256,
    layer_pattern=pattern_local_global(62, 5),  # (5L + G) x 10, tail LL
    scan_group=6,
    window=1024,
    rope_theta=1e4,             # local layers
    rope_theta_global=1e6,      # global layers
    qk_norm=True,
    sandwich_norm=True,
    scale_embeddings=True,
    dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="gemma3-27b-smoke",
    family="dense",
    n_layers=8,                 # one full (5L+G) unit + LL tail
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab=499,
    vocab_pad_to=64,
    layer_pattern=pattern_local_global(8, 5),
    scan_group=6,
    window=8,
    rope_theta=1e4,
    rope_theta_global=1e6,
    qk_norm=True,
    sandwich_norm=True,
    scale_embeddings=True,
    dtype=jnp.float32,
    q_block=16,
    kv_block=16,
    loss_block=16,
)
