"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Each module defines ``CONFIG`` (the exact published shape) and ``SMOKE``
(a reduced same-family config that runs a real step on CPU).
"""
from __future__ import annotations

import importlib

ARCHS: dict[str, str] = {
    "internvl2-1b": "internvl2_1b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "zamba2-2.7b": "zamba2_2p7b",
    "seamless-m4t-large-v2": "seamless_m4t_v2",
    "starcoder2-7b": "starcoder2_7b",
    "gemma3-27b": "gemma3_27b",
    "yi-6b": "yi_6b",
    "gemma2-2b": "gemma2_2b",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_smoke(arch: str):
    return _module(arch).SMOKE


def all_archs() -> tuple[str, ...]:
    return tuple(ARCHS)
