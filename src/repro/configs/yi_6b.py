"""yi-6b [dense] — 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-arch GQA with SwiGLU [arXiv:2403.04652]."""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    vocab_pad_to=256,           # already 250*256
    rope_theta=5e6,
    dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="yi-6b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=4,
    head_dim=8,
    d_ff=96,
    vocab=500,
    vocab_pad_to=64,
    rope_theta=5e6,
    dtype=jnp.float32,
    q_block=16,
    kv_block=16,
    loss_block=16,
)
