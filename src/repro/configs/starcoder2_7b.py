"""starcoder2-7b [dense] — 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152, RoPE, plain GELU FFN [arXiv:2402.19173]."""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    vocab_pad_to=256,           # already 192*256
    mlp_gated=False,
    mlp_act="gelu",
    rope_theta=1e5,
    dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="starcoder2-7b-smoke",
    family="dense",
    n_layers=2,
    d_model=72,
    n_heads=36,                 # keep the 36-head oddity
    n_kv_heads=4,
    head_dim=4,
    d_ff=128,
    vocab=512,
    vocab_pad_to=64,
    mlp_gated=False,
    mlp_act="gelu",
    dtype=jnp.float32,
    q_block=16,
    kv_block=16,
    loss_block=16,
)
