"""seamless-m4t-large-v2 [audio] — enc-dec transformer backbone, 24 encoder
+ 24 decoder layers, d_model=1024 16H (kv=16) d_ff=8192 vocab=256206
[arXiv:2308.11596]. The audio frontend is a STUB: input_specs provide
precomputed frame embeddings (per assignment). Plain (non-gated) FFN,
NLLB-style."""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,                # decoder layers
    enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    vocab_pad_to=256,           # -> 256256
    mlp_gated=False,
    mlp_act="relu",
    frontend="audio",
    frontend_len=1024,          # precomputed speech frames
    rope_theta=1e4,
    dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="seamless-m4t-large-v2-smoke",
    family="encdec",
    n_layers=2,
    enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=96,
    vocab=503,
    vocab_pad_to=64,
    mlp_gated=False,
    mlp_act="relu",
    frontend="audio",
    frontend_len=8,
    dtype=jnp.float32,
    q_block=16,
    kv_block=16,
    loss_block=16,
)
