"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000, alternating local/global attention, attn softcap 50,
logit softcap 30, head_dim=256 [arXiv:2408.00118]."""
import jax.numpy as jnp

from repro.models.config import ModelConfig, pattern_local_global

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    vocab_pad_to=256,
    layer_pattern=pattern_local_global(26, 1),  # alternating (L, G) x 13
    scan_group=2,
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    sandwich_norm=True,
    scale_embeddings=True,
    rope_theta=1e4,
    dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="gemma2-2b-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab=499,
    vocab_pad_to=64,
    layer_pattern=pattern_local_global(4, 1),
    scan_group=2,
    window=8,
    attn_softcap=50.0,
    logit_softcap=30.0,
    sandwich_norm=True,
    scale_embeddings=True,
    dtype=jnp.float32,
    q_block=16,
    kv_block=16,
    loss_block=16,
)
