"""internvl2-1b [vlm] — InternViT frontend (stub) + InternLM2 backbone.
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655 [arXiv:2404.16821].
The vision frontend is a STUB: input_specs provide precomputed patch
embeddings (per assignment)."""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    vocab_pad_to=256,           # 151655 -> 151808 (16-way shardable)
    rope_theta=1e6,             # InternLM2 long-context base
    frontend="vision",
    frontend_len=256,           # ViT patch embeddings, precomputed
    dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="internvl2-1b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=14,                 # keep the odd head count (divisibility bugs)
    n_kv_heads=2,
    head_dim=8,
    d_ff=96,
    vocab=503,
    vocab_pad_to=64,
    rope_theta=1e6,
    frontend="vision",
    frontend_len=8,
    dtype=jnp.float32,
    q_block=16,
    kv_block=16,
    loss_block=16,
)
