"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16, Mamba1 architecture [arXiv:2410.05355]."""
import jax.numpy as jnp

from repro.models.config import MAMBA1, ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,                  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=65024,
    vocab_pad_to=256,           # already 254*256
    layer_pattern=(MAMBA1,) * 64,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,               # d_inner = 8192
    ssm_dt_rank=256,            # 4096 // 16
    ssm_chunk=256,
    dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="falcon-mamba-7b-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab=499,
    vocab_pad_to=64,
    layer_pattern=(MAMBA1,) * 2,
    ssm_state=8,
    ssm_conv=4,
    ssm_expand=2,
    ssm_chunk=8,
    dtype=jnp.float32,
    loss_block=16,
)
