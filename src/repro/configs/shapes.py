"""Assigned input shapes and per-(arch x shape) input specs.

``input_specs`` returns ShapeDtypeStructs only — the dry-run lowers
against them with zero allocation (weak-type-correct, shardable).
LM shapes are seq_len x global_batch; decode_*/long_* lower serve_step
(one token against a seq_len cache), not train_step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: run only for SSM/hybrid archs
# (O(1)-state decode). Pure full-attention archs skip it (DESIGN.md §4).
_LONG_OK_FAMILIES = ("ssm", "hybrid")


def applicable(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.family in _LONG_OK_FAMILIES
    return True


def skip_reason(cfg: ModelConfig, shape: str) -> str | None:
    if applicable(cfg, shape):
        return None
    return (f"{cfg.name} is pure full-attention ({cfg.family}); 500k-token "
            "decode requires sub-quadratic attention (DESIGN.md §4)")


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def token_split(cfg: ModelConfig, seq_len: int) -> tuple[int, int]:
    """(frontend_len, text_len) for decoder inputs of total length seq."""
    if cfg.frontend == "vision":
        fl = min(cfg.frontend_len, seq_len // 2)
        return fl, seq_len - fl
    return 0, seq_len


def train_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    fl, st = token_split(cfg, s)
    specs = {
        "tokens": _sds((b, st), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
        "loss_mask": _sds((b, s), jnp.float32),
    }
    if fl:
        specs["frontend"] = _sds((b, fl, cfg.d_model), cfg.dtype)
    if cfg.is_encdec:
        specs["enc_frames"] = _sds((b, cfg.frontend_len, cfg.d_model),
                                   cfg.dtype)
        specs["tokens"] = _sds((b, s), jnp.int32)  # decoder tokens, full s
    return specs


def prefill_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    specs = train_specs(cfg, shape)
    specs.pop("labels")
    specs.pop("loss_mask")
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """One new token against a cache of ``seq_len`` tokens."""
    b = shape.global_batch
    specs = {
        "tokens": _sds((b,), jnp.int32),
        "lengths": _sds((b,), jnp.int32),
    }
    return specs
