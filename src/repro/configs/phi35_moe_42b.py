"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct]."""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab=32064,
    vocab_pad_to=256,           # -> 32256
    n_experts=16,
    top_k=2,
    rope_theta=1e4,
    dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="phi3.5-moe-42b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    head_dim=8,
    d_ff=48,
    vocab=512,
    vocab_pad_to=64,
    n_experts=4,
    top_k=2,
    dtype=jnp.float32,
    q_block=16,
    kv_block=16,
    loss_block=16,
)
