"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    vocab_pad_to=256,           # -> 49408
    n_experts=32,
    top_k=8,
    rope_theta=1e4,
    dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="granite-moe-1b-a400m-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=16,
    n_kv_heads=8,
    head_dim=8,
    d_ff=32,
    vocab=499,
    vocab_pad_to=64,
    n_experts=4,
    top_k=2,
    dtype=jnp.float32,
    q_block=16,
    kv_block=16,
    loss_block=16,
)
