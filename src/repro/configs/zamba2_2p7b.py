"""zamba2-2.7b [hybrid] — 54L Mamba2 backbone, d_model=2560, one SHARED
attention+MLP block (32H GQA kv=32, d_ff=10240) applied every 6 layers,
vocab=32000, ssm_state=64 [arXiv:2411.15242]."""
import jax.numpy as jnp

from repro.models.config import MAMBA2, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,                 # shared block MLP
    vocab=32000,
    vocab_pad_to=256,           # already 125*256
    layer_pattern=(MAMBA2,) * 54,
    shared_attn_every=6,        # 9 applications of the shared block
    scan_group=6,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,               # d_inner = 5120
    ssm_head_dim=64,            # 80 SSD heads
    ssm_chunk=256,
    rope_theta=1e4,
    dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="zamba2-2.7b-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=96,
    vocab=499,
    vocab_pad_to=64,
    layer_pattern=(MAMBA2,) * 4,
    shared_attn_every=2,
    scan_group=2,
    ssm_state=8,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=8,
    dtype=jnp.float32,
    q_block=16,
    kv_block=16,
    loss_block=16,
)
