"""AdamW, functional. Moments are fp32 and sharded exactly like their
params (the 2-D FSDPxTP layout from parallel/sharding.py, applied by the
launcher via the same logical-axes tree), so optimizer memory scales
1/(data*model) — ZeRO-flavored without a separate partitioner."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adamw_init(params) -> AdamWState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        mu=jax.tree.map(zeros32, params),
        nu=jax.tree.map(zeros32, params),
        count=jnp.zeros((), jnp.int32),
    )


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                      for l in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
):
    """Returns (new_params, new_state, metrics). Params keep their input
    dtype (bf16 master-in-compute-dtype convention; flip to fp32 masters
    by casting the tree at init)."""
    grads32, gnorm = clip_by_global_norm(grads, max_grad_norm)
    count = state.count + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(g, m, v, p):
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads32)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(new_m, new_v, count), {"grad_norm": gnorm}
