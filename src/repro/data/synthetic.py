"""Deterministic, shardable, resumable synthetic token pipeline.

Every batch is a pure function of (seed, step, shard) — a preempted or
re-meshed job resumes mid-epoch with exact reproducibility (the data
side of the fault-tolerance story). Tokens follow a Zipf-ish mixture so
the loss curve is non-trivial; labels are next-token shifted.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig
from repro.configs.shapes import token_split


def _rng(seed: int, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))


def _tokens(rng, shape, vocab: int) -> np.ndarray:
    """Zipf-mixture token stream (bounded to vocab)."""
    z = rng.zipf(1.3, size=shape).astype(np.int64)
    u = rng.integers(0, vocab, size=shape)
    pick = rng.random(shape) < 0.5
    t = np.where(pick, np.minimum(z, vocab - 1), u)
    return t.astype(np.int32)


def make_batch(cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0,
               step: int = 0, shard: int = 0) -> dict:
    """One training batch matching train_specs(cfg) layouts."""
    rng = _rng(seed, step, shard)
    fl, st = token_split(cfg, seq)
    if cfg.is_encdec:
        fl, st = 0, seq
    stream = _tokens(rng, (batch, st + 1), cfg.vocab)
    tokens = stream[:, :-1]
    text_labels = stream[:, 1:]
    labels = np.zeros((batch, seq), dtype=np.int32)
    mask = np.zeros((batch, seq), dtype=np.float32)
    labels[:, fl:] = text_labels
    mask[:, fl:] = 1.0
    out = {"tokens": tokens, "labels": labels, "loss_mask": mask}
    if fl:
        out["frontend"] = rng.standard_normal(
            (batch, fl, cfg.d_model)).astype(np.float32) * 0.02
    if cfg.is_encdec:
        el = cfg.frontend_len
        out["enc_frames"] = rng.standard_normal(
            (batch, el, cfg.d_model)).astype(np.float32) * 0.02
    return out


@dataclasses.dataclass
class SyntheticDataset:
    """Step-indexed dataset: ``batch_at(step)`` is stateless & exact-resume.

    ``shard``/``num_shards`` split the global batch for per-host loading
    (each host materializes only its rows — the 1000-node data path).
    """

    cfg: ModelConfig
    global_batch: int
    seq: int
    seed: int = 0
    shard: int = 0
    num_shards: int = 1

    def __post_init__(self):
        assert self.global_batch % self.num_shards == 0

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.num_shards

    def batch_at(self, step: int) -> dict:
        return make_batch(self.cfg, self.local_batch, self.seq,
                          seed=self.seed, step=step, shard=self.shard)
