from repro.data.synthetic import SyntheticDataset, make_batch  # noqa: F401
